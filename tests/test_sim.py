"""CFD substrate: spectral solver exactness + flat-plate generator."""

import jax
import jax.numpy as jnp
import math
import numpy as np
import pytest

from repro.sim import flatplate as fp
from repro.sim import spectral as sp


@pytest.fixture(scope="module")
def cfg():
    return sp.NSConfig(n=16, nu=0.05, dt=0.01)


def test_tgv2d_exact_decay(cfg):
    """2-D Taylor-Green is an exact NS solution: E(t) = E0·e^{-4νt}."""
    state = sp.taylor_green_2d(cfg)
    e0 = float(sp.energy(cfg, state))
    for _ in range(20):
        state = sp.step(cfg, state)
    e = float(sp.energy(cfg, state))
    expected = e0 * math.exp(-4 * cfg.nu * float(state.t))
    assert abs(e - expected) / expected < 1e-5


def test_divergence_free(cfg):
    state = sp.taylor_green(cfg)
    for _ in range(10):
        state = sp.step(cfg, state)
    assert float(sp.max_divergence(cfg, state)) < 1e-10


def test_energy_monotone_decay_unforced(cfg):
    state = sp.taylor_green(cfg)
    es = [float(sp.energy(cfg, state))]
    for _ in range(8):
        state = sp.step(cfg, state)
        es.append(float(sp.energy(cfg, state)))
    assert all(a >= b for a, b in zip(es, es[1:]))


def test_forcing_sustains_energy():
    cfg = sp.NSConfig(n=16, nu=0.02, dt=0.01, forcing=True, f_amp=0.15)
    state = sp.random_turbulence(cfg, jax.random.key(0), e0=0.3)
    e0 = float(sp.energy(cfg, state))
    for _ in range(30):
        state = sp.step(cfg, state)
    e = float(sp.energy(cfg, state))
    assert e > 0.2 * e0            # forced flow does not die out


def test_snapshot_shape_and_finite(cfg):
    state = sp.taylor_green(cfg)
    snap = sp.snapshot(cfg, state)
    assert snap.shape == (4, cfg.n_points)
    assert bool(jnp.isfinite(snap).all())
    # pressure gauge: zero mean
    assert abs(float(snap[0].mean())) < 1e-6


def test_partition_snapshot_roundtrip(cfg):
    state = sp.taylor_green(cfg)
    snap = sp.snapshot(cfg, state)
    parts = sp.partition_snapshot(snap, 8)
    assert parts.shape == (8, 4, cfg.n_points // 8)
    rebuilt = parts.transpose(1, 0, 2).reshape(4, -1)
    np.testing.assert_allclose(np.asarray(rebuilt), np.asarray(snap))


class TestFlatPlate:
    def test_shapes_and_coords(self):
        cfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        coords = fp.grid_coords(cfg)
        snap = fp.snapshot(cfg, jax.random.key(0), 0)
        assert coords.shape == (cfg.n_points, 3)
        assert snap.shape == (4, cfg.n_points)
        assert bool(jnp.isfinite(snap).all())

    def test_wall_normal_stretching(self):
        cfg = fp.FlatPlateConfig(nx=4, ny=16, nz=2)
        coords = fp.grid_coords(cfg)
        y = np.unique(np.asarray(coords[:, 1]))
        dy = np.diff(y)
        assert dy[0] < dy[-1] * 0.5          # clustered at the wall

    def test_temporal_correlation(self):
        cfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        s0 = fp.snapshot(cfg, jax.random.key(0), 0)
        s1 = fp.snapshot(cfg, jax.random.key(0), 1)
        s9 = fp.snapshot(cfg, jax.random.key(0), 40)
        c1 = float(jnp.corrcoef(s0[1], s1[1])[0, 1])
        c9 = float(jnp.corrcoef(s0[1], s9[1])[0, 1])
        assert c1 > 0.9 and c9 < c1          # decorrelates over time

    def test_deterministic(self):
        cfg = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
        a = fp.snapshot(cfg, jax.random.key(3), 7)
        b = fp.snapshot(cfg, jax.random.key(3), 7)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batch(self):
        cfg = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
        batch = fp.snapshot_batch(cfg, jax.random.key(0), 0, 3)
        assert batch.shape == (3, 4, cfg.n_points)


# ---------------------------------------------------------------------------
# Halo exchange + domain-decomposed FD solver (sim.halo / sim.distributed)
# ---------------------------------------------------------------------------

from repro.sim import distributed as fd
from repro.sim import halo as hl


class TestPadReference:
    def test_periodic_wraps(self):
        x = jnp.arange(12.0).reshape(6, 2)
        p = hl.pad_reference(x, width=2)
        np.testing.assert_array_equal(np.asarray(p[:2]), np.asarray(x[-2:]))
        np.testing.assert_array_equal(np.asarray(p[-2:]), np.asarray(x[:2]))
        np.testing.assert_array_equal(np.asarray(p[2:-2]), np.asarray(x))

    @pytest.mark.parametrize("wall,sign", [("zero", 0.0), ("reflect", 1.0),
                                           ("reflect_neg", -1.0)])
    def test_wall_modes(self, wall, sign):
        x = jnp.arange(1.0, 13.0).reshape(6, 2)
        p = hl.pad_reference(x, width=2, boundary="wall", wall=wall)
        lo = sign * np.asarray(jnp.flip(x[:2], axis=0))
        hi = sign * np.asarray(jnp.flip(x[-2:], axis=0))
        np.testing.assert_array_equal(np.asarray(p[:2]), lo)
        np.testing.assert_array_equal(np.asarray(p[-2:]), hi)

    def test_validation(self):
        x = jnp.zeros((4, 4))
        with pytest.raises(ValueError, match="boundary"):
            hl.pad_reference(x, boundary="open")
        with pytest.raises(ValueError, match="wall mode"):
            hl.pad_reference(x, boundary="wall", wall="slip")
        with pytest.raises(ValueError, match="width"):
            hl.pad_reference(x, width=0)
        with pytest.raises(ValueError, match="exceeds"):
            hl.pad_reference(x, width=5)


class TestHaloExchange:
    """Single-shard shard_map: the ppermute path must reproduce the
    global-array reference exactly (multi-shard parity is the slow
    subprocess test below)."""

    @pytest.mark.parametrize("boundary,wall", [("periodic", "zero"),
                                               ("wall", "zero"),
                                               ("wall", "reflect"),
                                               ("wall", "reflect_neg")])
    @pytest.mark.parametrize("width", [1, 2])
    def test_one_shard_matches_reference(self, boundary, wall, width):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import space_mesh
        mesh = space_mesh(1)
        x = jax.random.normal(jax.random.key(0), (8, 3))
        f = shard_map(
            lambda b: hl.halo_exchange(b, axis="space", width=width,
                                       boundary=boundary, wall=wall),
            mesh=mesh, in_specs=(P("space", None),),
            out_specs=P("space", None), check_rep=False)
        np.testing.assert_array_equal(
            np.asarray(f(x)),
            np.asarray(hl.pad_reference(x, width=width, boundary=boundary,
                                        wall=wall)))


class TestFDConfig:
    def test_field_validation(self):
        with pytest.raises(ValueError, match="n must"):
            fd.FDConfig(n=2)
        with pytest.raises(ValueError, match="nu must"):
            fd.FDConfig(nu=0.0)
        with pytest.raises(ValueError, match="dt must"):
            fd.FDConfig(dt=-1.0)
        with pytest.raises(ValueError, match="jacobi_iters"):
            fd.FDConfig(jacobi_iters=0)

    def test_divisibility_up_front(self):
        cfg = fd.FDConfig(n=32)
        cfg.validate_shards(4)                 # divides: fine
        with pytest.raises(ValueError, match="do not divide"):
            cfg.validate_shards(5)
        with pytest.raises(ValueError, match="n_shards"):
            cfg.validate_shards(0)

    def test_make_step_validates_mesh(self):
        from repro.parallel.sharding import space_mesh
        # 1 shard divides anything — builds fine even for odd n
        fd.make_step(fd.FDConfig(n=9), space_mesh(1))
        # the 2-shard ask fails in validate_shards, before any tracing
        with pytest.raises(ValueError, match="do not divide"):
            fd.FDConfig(n=9).validate_shards(2)


class TestFDSolver:
    @pytest.fixture(scope="class")
    def cfg(self):
        return fd.FDConfig(n=32, nu=0.01, dt=2e-3, jacobi_iters=64)

    def test_taylor_green_discrete_decay(self, cfg):
        """The discrete TG mode decays by exactly (1 - 2 nu dt lambda_h)
        per step — advection is projected away, leaving pure discrete
        diffusion (the solver's analytic anchor)."""
        step = fd.make_step(cfg)
        s = fd.taylor_green(cfg)
        e0 = float(fd.energy(s))
        g = fd.taylor_green_factor(cfg)
        for k in (10, 30, 50):
            while int(s.step) < k:
                s = step(s)
            e = float(fd.energy(s))
            pred = e0 * g ** (2 * k)
            assert abs(e - pred) / pred < 1e-4, (k, e, pred)

    def test_taylor_green_analytic_decay(self, cfg):
        """...and the discrete rate converges on the continuum
        E(t) = E0 exp(-4 nu t) (within the h^2 truncation at n=32)."""
        step = fd.make_step(cfg)
        s = fd.taylor_green(cfg)
        e0 = float(fd.energy(s))
        for _ in range(50):
            s = step(s)
        expected = e0 * math.exp(-4 * cfg.nu * float(s.t))
        assert abs(float(fd.energy(s)) - expected) / expected < 1e-3

    def test_matches_spectral_energy(self, cfg):
        """FD vs pseudo-spectral on the same TG flow: energies agree to
        the scheme's truncation order over the same physical time."""
        scfg = sp.NSConfig(n=cfg.n, nu=cfg.nu, dt=cfg.dt)
        fstep = fd.make_step(cfg)
        f, s = fd.taylor_green(cfg), sp.taylor_green_2d(scfg)
        for _ in range(40):
            f, s = fstep(f), sp.step(scfg, s)
        ef, es = float(fd.energy(f)), float(sp.energy(scfg, s))
        assert abs(ef - es) / es < 5e-3

    def test_max_divergence_bound(self, cfg):
        step = fd.make_step(cfg)
        s = fd.taylor_green(cfg)
        for _ in range(20):
            s = step(s)
        assert float(fd.max_divergence(cfg, s)) < 1e-5

    def test_decaying_turbulence(self, cfg):
        s = fd.decaying_turbulence(cfg, jax.random.key(1), e0=0.5)
        assert abs(float(fd.energy(s)) - 0.5) < 1e-4
        # streamfunction construction: exactly discretely divergence-free
        assert float(fd.max_divergence(cfg, s)) < 1e-5
        step = fd.make_step(cfg)
        e_prev = float(fd.energy(s))
        for _ in range(20):
            s = step(s)
        # unforced: decays, stays finite, divergence at the Jacobi residual
        assert float(fd.energy(s)) < e_prev
        assert bool(jnp.isfinite(s.u).all())
        assert float(fd.max_divergence(cfg, s)) < 0.05

    def test_one_shard_parity(self, cfg):
        from repro.parallel.sharding import space_mesh
        mesh = space_mesh(1)
        ref, sh = fd.make_step(cfg), fd.make_step(cfg, mesh)
        a = fd.taylor_green(cfg)
        b = fd.shard_state(a, mesh)
        for _ in range(10):
            a, b = ref(a), sh(b)
        np.testing.assert_allclose(np.asarray(a.u), np.asarray(b.u),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(a.v), np.asarray(b.v),
                                   atol=1e-6)

    def test_make_producer_surface(self, cfg):
        step_fn, s0, es = fd.make_producer(cfg)
        assert es is None                      # off-mesh: unsharded
        s1, key, value = step_fn(s0, 0, 0)
        assert value.shape == (2, cfg.n, cfg.n)
        assert int(s1.step) == 1
        with pytest.raises(ValueError, match="unknown init"):
            fd.make_producer(cfg, init="laminar")


@pytest.mark.slow
class TestShardedSolverMultiDevice:
    def test_four_shard_parity_and_halo(self):
        from conftest import run_subprocess
        run_subprocess("""
            import numpy as np, jax, jax.numpy as jnp
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.parallel.sharding import space_mesh
            from repro.sim import distributed as fd
            from repro.sim import halo as hl

            mesh = space_mesh(4)
            cfg = fd.FDConfig(n=32, jacobi_iters=48)
            ref, sh = fd.make_step(cfg), fd.make_step(cfg, mesh)
            a = fd.taylor_green(cfg)
            b = fd.shard_state(a, mesh)
            for _ in range(20):
                a, b = ref(a), sh(b)
            np.testing.assert_allclose(np.asarray(a.u), np.asarray(b.u),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(a.v), np.asarray(b.v),
                                       atol=1e-5)

            # width-w halo parity against the global reference, both
            # boundary types, every wall mode
            x = jax.random.normal(jax.random.key(0), (16, 5))
            for boundary in ("periodic", "wall"):
                for wall in hl.WALL_MODES:
                    for w in (1, 2):
                        f = shard_map(
                            lambda blk: hl.halo_exchange(
                                blk, axis="space", width=w,
                                boundary=boundary, wall=wall),
                            mesh=mesh, in_specs=(P("space", None),),
                            out_specs=P("space", None), check_rep=False)
                        got = np.asarray(f(x)).reshape(4, -1, 5)
                        gp = np.asarray(hl.pad_reference(
                            x, width=w, boundary=boundary, wall=wall))
                        rows = 16 // 4
                        exp = np.stack([gp[i*rows : i*rows + rows + 2*w]
                                        for i in range(4)])
                        np.testing.assert_array_equal(got, exp), \\
                            (boundary, wall, w)

            # misdividing grid fails up front with the clear message
            try:
                fd.make_step(fd.FDConfig(n=30), mesh)
            except ValueError as e:
                assert "do not divide" in str(e)
            else:
                raise AssertionError("n=30 over 4 shards did not raise")
            print("OK")
        """, n_devices=4)
