"""Property-based scenario grid: ``plan.explain()``'s predicted store
dispatches must equal the measured ``StoreServer.stats()["op_count"]``
EXACTLY — not pointwise (PR 3's tests) but quantified over random
declarations drawn from the whole
(deployment x producer tier x trainer tier x ranks x chunk x emit_every x
bucketing) grid, where deployment now spans {local, colocated,
CLUSTERED}: on the clustered cells the predicted cross-mesh
``staged_transfers`` must equal the measured
``stats()["staged_transfers"]`` exactly too (per component and in
total).  The cached-watermark bookkeeping rides along: the producer
table's watermark must equal the statically predicted put count.

With hypothesis installed (CI) the grid is explored by strategy; without
it, a seeded-random sweep of the same space runs the same 50+ scenarios
deterministically, so the property is exercised everywhere the suite
runs.

The producer step emits *precomputed* snapshots (pure indexing) so that
jit-compiled executables are shared across scenarios (the step function
identity is a static jit arg) and runs stay cheap.
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, HealthCheck, given, settings, st

from repro.core import TableSpec
from repro.core import store as S
from repro.core.deployment import make_clustered_1d, make_colocated_1d
from repro.core.faults import FaultEvent, FaultPlan, RetryPolicy
from repro.insitu import (InSituSession, Producer, ServingClients,
                          ServingConsumer, TrainerConsumer)
from repro.ml import autoencoder as ae
from repro.ml import trainer as tr
from repro.sim import flatplate as fp

FCFG = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
N = FCFG.n_points
COORDS = fp.grid_coords(FCFG)
_SNAP_COUNT = 8
SNAPS = jnp.stack([fp.snapshot(FCFG, jax.random.key(0), t)
                   for t in range(_SNAP_COUNT)])
#: as small as the QuadConv AE goes — the property under test is dispatch
#: accounting, not model quality, and the epoch recompiles per scenario.
_TINY_AE = ae.AEConfig(n_points=N, mode="ref", latent=4, internal=4,
                       blocks=1, mlp_width=8, mlp_depth=2)


def _step(carry, rank, t):
    # Pure indexing — no in-dispatch solver math — so the emitted bytes
    # are placement-independent and the executable caches across runs.
    return carry, S.make_key(rank, t), SNAPS[t % _SNAP_COUNT]


def _make_deployment(kind: str):
    if kind == "colocated":
        return make_colocated_1d(ndim=2)
    if kind == "clustered":
        # degenerate on one visible device (client and db share it) —
        # the staging path and its telemetry are structural either way
        return make_clustered_1d()
    return None


def _run_scenario(*, ranks: int, steps: int, emit_every: int,
                  chunk: int | None, bucket: bool, producer_per_verb: bool,
                  trainer_tier: str | None, epochs: int, deployment: str,
                  capacity: int = 16):
    """Build one random declaration, run it sequentially, and assert the
    plan's dispatch (and, clustered, staged-transfer) predictions are
    exact."""
    carry = jnp.zeros(()) if ranks == 1 else jnp.zeros((ranks,))
    components = [Producer(
        _step, table="field", steps=steps, ranks=ranks, carry=carry,
        emit_every=emit_every, chunk=chunk, bucket=bucket,
        tier="per_verb" if producer_per_verb else None)]
    if trainer_tier is not None:
        cfg = tr.TrainerConfig(
            ae=_TINY_AE, epochs=epochs, gather=4, batch_size=2, lr=1e-3,
            fused=(trainer_tier == "fused"))
        components.append(TrainerConsumer(cfg, COORDS))
    sess = InSituSession(
        tables=[TableSpec("field", shape=(4, N), capacity=capacity,
                          engine="ring")],
        components=components,
        deployment=_make_deployment(deployment))
    plan = sess.plan()
    res = sess.run(plan=plan, sequential=True, max_wall_s=240)
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    # THE invariant: per-component predicted dispatches == measured, exactly.
    for entry in plan.components:
        assert res.op_delta(entry.name) == entry.store_dispatches, \
            (entry.name, entry.tier, res.op_delta(entry.name),
             entry.store_dispatches)
        assert res.staged_delta(entry.name) == entry.staged_transfers, \
            (entry.name, entry.tier, res.staged_delta(entry.name),
             entry.staged_transfers)
    stats = res.server.stats()
    assert stats["op_count"] == plan.store_dispatches
    # Clustered: the staged-transfer predictions are exact too; every
    # other deployment never stages.
    assert stats["staged_transfers"] == plan.staged_transfers
    if deployment != "clustered":
        assert plan.staged_transfers == 0
    # Watermark bookkeeping: cached count == statically predicted puts
    # == device ground truth.
    puts = ranks * S.capture_emit_count(steps, emit_every)
    assert res.server.watermark("field") == puts \
        == res.server.watermark_device("field")


_DEPLOYMENTS = ("none", "colocated", "clustered")


def _draw_scenario(rng: random.Random) -> dict:
    """One uniformly random point of the grid (the seeded fallback's
    generator; mirrors the hypothesis strategies below)."""
    return dict(
        ranks=rng.randint(1, 4),
        steps=rng.randint(4, 20),
        emit_every=rng.randint(1, 4),
        chunk=rng.choice([None, rng.randint(2, 12)]),
        bucket=rng.random() < 0.5,
        producer_per_verb=rng.random() < 0.3,
        trainer_tier=rng.choice([None, "fused", "fused", "per_verb"]),
        epochs=rng.randint(1, 2),
        deployment=rng.choice(_DEPLOYMENTS),
    )


@pytest.mark.slow
@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis present: the quantified form below "
                           "covers the grid")
def test_seeded_scenario_grid():
    """Deterministic 50-scenario sweep of the grid (the no-hypothesis
    environment's form of the property)."""
    rng = random.Random(0)
    for i in range(50):
        sc = _draw_scenario(rng)
        try:
            _run_scenario(**sc)
        except AssertionError as e:  # name the failing scenario
            raise AssertionError(f"scenario #{i} {sc}: {e}") from e


@pytest.mark.slow
@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large,
                                 HealthCheck.large_base_example])
@given(ranks=st.integers(1, 4),
       steps=st.integers(4, 20),
       emit_every=st.integers(1, 4),
       chunk=st.one_of(st.none(), st.integers(2, 12)),
       bucket=st.booleans(),
       producer_per_verb=st.booleans(),
       trainer_tier=st.sampled_from([None, "fused", "per_verb"]),
       epochs=st.integers(1, 2),
       deployment=st.sampled_from(_DEPLOYMENTS))
def test_hypothesis_scenario_grid(ranks, steps, emit_every, chunk, bucket,
                                  producer_per_verb, trainer_tier, epochs,
                                  deployment):
    """The same property, hypothesis-quantified (shrinks to a minimal
    counterexample on failure)."""
    _run_scenario(ranks=ranks, steps=steps, emit_every=emit_every,
                  chunk=chunk, bucket=bucket,
                  producer_per_verb=producer_per_verb,
                  trainer_tier=trainer_tier, epochs=epochs,
                  deployment=deployment)


# ---------------------------------------------------------------------------
# Chaos grid: the same exactness property under seeded fault injection
# ---------------------------------------------------------------------------
#
# Three claims per (seed, deployment) cell, against a FaultPlan.random
# drawing dropped/duplicated chunk transfers, transient unavailability
# windows, producer/trainer crashes, and store snapshots/restarts:
#
#   (a) the run COMPLETES (every fault is absorbed or recovered from);
#   (b) the final table contents and TrainState are BIT-IDENTICAL to the
#       fault-free baseline (exactly-once delivery + checkpoint-resumed
#       rng streams + deterministic WAL replay);
#   (c) the plan's predicted dispatches and staged transfers — retries,
#       replay ops and re-staged hops included — equal the measured
#       ``stats()`` counters EXACTLY, as do the predicted fault totals.
#
# The fault-free baseline runs with an *empty armed* FaultPlan so both
# runs take the identical logged (chunk-id + WAL) code path.

_FAST_RETRY = dict(interval=1e-4, max_interval=1e-3)


def _chaos_session(deployment: str, faults: FaultPlan, *,
                   producer_per_verb: bool, steps: int, emit_every: int,
                   chunk: int, epochs: int, capacity: int = 16):
    cfg = tr.TrainerConfig(ae=_TINY_AE, epochs=epochs, gather=4,
                           batch_size=2, lr=1e-3, fused=True)
    return InSituSession(
        tables=[TableSpec("field", shape=(4, N), capacity=capacity,
                          engine="ring")],
        components=[
            Producer(_step, table="field", steps=steps, ranks=1,
                     carry=jnp.zeros(()), emit_every=emit_every,
                     chunk=chunk,
                     tier="per_verb" if producer_per_verb else None),
            TrainerConsumer(cfg, COORDS)],
        deployment=_make_deployment(deployment),
        faults=faults)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def _run_chaos_scenario(seed: int, deployment: str):
    rng = random.Random(seed)
    shape = dict(
        producer_per_verb=rng.random() < 0.3,
        steps=rng.randint(6, 12),
        emit_every=rng.randint(1, 2),
        chunk=rng.randint(2, 5),
        epochs=rng.randint(1, 2),
    )
    retry = RetryPolicy(seed=seed, **_FAST_RETRY)
    baseline = _chaos_session(
        deployment, FaultPlan(events=(), retry=retry), **shape).run(
        sequential=True, max_wall_s=240)
    assert baseline.ok, {k: v.error
                         for k, v in baseline.run.components.items()}
    faults = FaultPlan.random(
        seed, tables=("field",), verbs=("put", "capture", "sample"),
        components=("producer", "trainer"), n_events=3,
        max_index=shape["steps"], retry=retry)
    sess = _chaos_session(deployment, faults, **shape)
    plan = sess.plan()
    res = sess.run(plan=plan, sequential=True, max_wall_s=240)
    # (a) the chaos run completes
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    # (c) exact predictions, retries/replays/restages included
    for entry in plan.components:
        assert res.op_delta(entry.name) == entry.store_dispatches, \
            (entry.name, entry.tier, res.op_delta(entry.name),
             entry.store_dispatches)
        assert res.staged_delta(entry.name) == entry.staged_transfers, \
            (entry.name, entry.tier, res.staged_delta(entry.name),
             entry.staged_transfers)
        centry = res.run.components[entry.name]
        assert centry.retries == entry.retries, entry.name
        assert centry.restarts == entry.restarts, entry.name
    stats = res.server.stats()
    assert stats["op_count"] == plan.store_dispatches
    assert stats["staged_transfers"] == plan.staged_transfers
    for key, predicted in plan.faults:
        assert stats[key] == predicted, (key, predicted, stats[key])
    # (b) the data plane converged to the fault-free run, bit for bit
    assert res.server.watermark("field") \
        == baseline.server.watermark("field") \
        == res.server.watermark_device("field")
    assert res.server.valid_count("field") \
        == baseline.server.valid_count("field")
    for a, b in zip(_leaves(baseline.server.checkout("field")),
                    _leaves(res.server.checkout("field"))):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(_leaves(baseline.output("trainer").state),
                    _leaves(res.output("trainer").state)):
        np.testing.assert_array_equal(a, b)


_CHAOS_SEEDS = tuple(range(9))


@pytest.mark.chaos
@pytest.mark.parametrize("deployment", _DEPLOYMENTS)
def test_chaos_smoke(deployment):
    """One seeded fault scenario per deployment (the fast CI gate)."""
    _run_chaos_scenario(0, deployment)


@pytest.mark.chaos
@pytest.mark.slow
@pytest.mark.parametrize("deployment", _DEPLOYMENTS)
def test_chaos_grid(deployment):
    """The full grid: 9 seeds x 3 deployments = 27 seeded fault combos."""
    for seed in _CHAOS_SEEDS:
        try:
            _run_chaos_scenario(seed, deployment)
        except AssertionError as e:
            raise AssertionError(
                f"chaos seed {seed} ({deployment}): {e}") from e


@pytest.mark.chaos
def test_concurrent_store_restart_recovers():
    """Acceptance: a mid-run store restart with a LIVE producer and
    trainer (concurrent threads, not sequential) recovers via snapshot +
    WAL replay and finishes with the fault-free watermark/valid_count."""
    shape = dict(producer_per_verb=False, steps=12, emit_every=1, chunk=2,
                 epochs=3)
    retry = RetryPolicy(**_FAST_RETRY)
    baseline = _chaos_session(
        "none", FaultPlan(events=(), retry=retry), **shape).run(
        max_wall_s=240)
    assert baseline.ok, {k: v.error
                         for k, v in baseline.run.components.items()}
    faults = FaultPlan(events=(
        FaultEvent("snapshot", table="field", at=2),
        FaultEvent("restart", table="field", at=5)), retry=retry)
    res = _chaos_session("none", faults, **shape).run(max_wall_s=240)
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    assert res.server.stats()["recoveries"] == 1
    assert res.server.watermark("field") \
        == baseline.server.watermark("field") == 12
    assert res.server.watermark("field") \
        == res.server.watermark_device("field")
    assert res.server.valid_count("field") \
        == baseline.server.valid_count("field")
    assert len(res.output("trainer").history) == shape["epochs"]


# ---------------------------------------------------------------------------
# Serving grid: exactly-once answers + exact dispatch/batch/swap predictions
# ---------------------------------------------------------------------------
#
# The serving plane's form of THE invariant, quantified over random
# (client count x arrival order x batch size x tier x deployment) points:
#
#   (a) every request is answered EXACTLY ONCE (the responses dict holds
#       precisely the submitted (client, seq) keys, each with the model's
#       output for that request, and the results watermark equals the
#       request total);
#   (b) the plan's predicted store dispatches, drained batches, staged
#       transfers and model swaps equal the measured ``stats()`` deltas —
#       per component and in total — for ANY arrival interleave
#       (``order_seed`` shuffles the submission order; round-robin
#       discovery canonicalises admission, so the batch count stays
#       ``ceil(total / max_batch)``).

_SERVE_SHAPE = (2, 4)


def _serve_feed(c, s):
    # Payload encodes (client, seq) so responses are per-request unique.
    return jnp.full(_SERVE_SHAPE, float(100 * c + s))


def _serve_model(p, x):
    return p * x + 1.0


def _serve_preload(server):
    server.set_model("m", _serve_model, jnp.asarray(2.0))


def _serving_session(*, clients: int, requests: int, max_batch: int,
                     tier: str | None, order_seed: int | None,
                     deployment: str, faults: FaultPlan | None = None):
    return InSituSession(
        tables=[TableSpec("sreq", shape=_SERVE_SHAPE, capacity=32,
                          engine="ring"),
                TableSpec("sres", shape=_SERVE_SHAPE, capacity=32,
                          engine="ring")],
        components=[
            ServingClients(_serve_feed, table="sreq", clients=clients,
                           requests=requests, submit=True, collect=False,
                           order_seed=order_seed, name="writers"),
            ServingConsumer("m", table="sreq", results="sres",
                            clients=clients, requests=requests,
                            max_batch=max_batch, tier=tier, name="serving"),
            ServingClients(_serve_feed, table="sreq", clients=clients,
                           requests=requests, submit=False, collect=True,
                           name="readers")],
        deployment=_make_deployment(deployment),
        faults=faults)


def _run_serving_scenario(*, clients: int, requests: int, max_batch: int,
                          tier: str, order_seed: int | None,
                          deployment: str):
    total = clients * requests
    sess = _serving_session(clients=clients, requests=requests,
                            max_batch=max_batch, tier=tier,
                            order_seed=order_seed, deployment=deployment)
    plan = sess.plan()
    res = sess.run(plan=plan, sequential=True, preload=_serve_preload,
                   max_wall_s=240)
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    # (b) exact per-component and total predictions
    for entry in plan.components:
        assert res.op_delta(entry.name) == entry.store_dispatches, \
            (entry.name, entry.tier, res.op_delta(entry.name),
             entry.store_dispatches)
        assert res.staged_delta(entry.name) == entry.staged_transfers, \
            (entry.name, entry.tier, res.staged_delta(entry.name),
             entry.staged_transfers)
    stats = res.server.stats()
    assert stats["op_count"] == plan.store_dispatches
    assert stats["staged_transfers"] == plan.staged_transfers
    if deployment != "clustered":
        assert plan.staged_transfers == 0
    assert stats["model_swaps"] == plan.model_swaps \
        == (1 if tier == "continuous_batch" else 0)
    serving = res.output("serving")
    assert serving.steps == total
    if tier == "continuous_batch":
        assert serving.batches == -(-total // max_batch)
        assert serving.swaps == 1
    # (a) exactly-once: precisely the submitted keys, each answered once
    out = res.output("readers")
    assert sorted(out.responses) == [(c, s) for c in range(clients)
                                     for s in range(requests)]
    for (c, s), v in out.responses.items():
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(_serve_model(2.0, _serve_feed(c, s))))
    assert res.server.watermark("sres") == total \
        == res.server.watermark_device("sres")


def _draw_serving_scenario(rng: random.Random) -> dict:
    return dict(
        clients=rng.randint(1, 4),
        requests=rng.randint(1, 5),
        max_batch=rng.randint(1, 6),
        tier=rng.choice(["continuous_batch", "continuous_batch",
                         "three_step"]),
        order_seed=rng.choice([None, rng.randint(0, 10**6)]),
        deployment=rng.choice(_DEPLOYMENTS),
    )


def test_serving_grid_seeded():
    """Deterministic 24-scenario sweep of the serving grid (runs in
    tier-1 everywhere; the hypothesis twin below shrinks on failure)."""
    rng = random.Random(7)
    for i in range(24):
        sc = _draw_serving_scenario(rng)
        try:
            _run_serving_scenario(**sc)
        except AssertionError as e:  # name the failing scenario
            raise AssertionError(f"serving scenario #{i} {sc}: {e}") from e


@pytest.mark.slow
@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large,
                                 HealthCheck.large_base_example])
@given(clients=st.integers(1, 4),
       requests=st.integers(1, 5),
       max_batch=st.integers(1, 6),
       tier=st.sampled_from(["continuous_batch", "three_step"]),
       order_seed=st.one_of(st.none(), st.integers(0, 10**6)),
       deployment=st.sampled_from(_DEPLOYMENTS))
def test_hypothesis_serving_grid(clients, requests, max_batch, tier,
                                 order_seed, deployment):
    """The serving property, hypothesis-quantified."""
    _run_serving_scenario(clients=clients, requests=requests,
                          max_batch=max_batch, tier=tier,
                          order_seed=order_seed, deployment=deployment)


# -- serving chaos cells -----------------------------------------------------
#
# The PR 6 claims, re-quantified over the serving plane: dropped
# request/response transfers, transient-unavailable windows on
# put/get/serve, client and consumer crashes, and store
# snapshots/restarts — the run completes, every response is bit-identical
# to the fault-free baseline, and the predicted dispatch/retry/swap
# counters stay exact (no torn model version: ``model_swaps`` is still
# exactly the plan's prediction).


def _run_serving_chaos(seed: int, deployment: str):
    rng = random.Random(seed)
    shape = dict(
        clients=rng.randint(2, 3),
        requests=rng.randint(2, 4),
        max_batch=rng.randint(1, 4),
        tier=rng.choice(["continuous_batch", "three_step"]),
        order_seed=rng.choice([None, rng.randint(0, 10**6)]),
    )
    total = shape["clients"] * shape["requests"]
    retry = RetryPolicy(seed=seed, **_FAST_RETRY)
    baseline = _serving_session(
        deployment=deployment, faults=FaultPlan(events=(), retry=retry),
        **shape).run(sequential=True, preload=_serve_preload, max_wall_s=240)
    assert baseline.ok, {k: v.error
                         for k, v in baseline.run.components.items()}
    faults = FaultPlan.random(
        seed, tables=("sreq", "sres"), verbs=("put", "get", "serve"),
        components=("writers", "serving"), n_events=3, max_index=total,
        retry=retry)
    sess = _serving_session(deployment=deployment, faults=faults, **shape)
    plan = sess.plan()
    res = sess.run(plan=plan, sequential=True, preload=_serve_preload,
                   max_wall_s=240)
    # (a) the chaos run completes
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    # (c) exact predictions, retries/replays/swaps included
    for entry in plan.components:
        assert res.op_delta(entry.name) == entry.store_dispatches, \
            (entry.name, entry.tier, res.op_delta(entry.name),
             entry.store_dispatches)
        assert res.staged_delta(entry.name) == entry.staged_transfers, \
            (entry.name, entry.tier, res.staged_delta(entry.name),
             entry.staged_transfers)
        centry = res.run.components[entry.name]
        assert centry.retries == entry.retries, entry.name
        assert centry.restarts == entry.restarts, entry.name
    stats = res.server.stats()
    assert stats["op_count"] == plan.store_dispatches
    assert stats["staged_transfers"] == plan.staged_transfers
    assert stats["model_swaps"] == plan.model_swaps
    for key, predicted in plan.faults:
        assert stats[key] == predicted, (key, predicted, stats[key])
    # (b) every response bit-identical to the fault-free run
    bout = baseline.output("readers").responses
    out = res.output("readers").responses
    assert sorted(out) == sorted(bout)
    for k in bout:
        np.testing.assert_array_equal(np.asarray(bout[k]),
                                      np.asarray(out[k]))
    assert res.server.watermark("sres") == total \
        == res.server.watermark_device("sres")


_SERVING_CHAOS_SEEDS = tuple(range(9))


@pytest.mark.chaos
@pytest.mark.parametrize("deployment", _DEPLOYMENTS)
def test_serving_chaos_smoke(deployment):
    """One seeded serving fault scenario per deployment (fast CI gate)."""
    _run_serving_chaos(0, deployment)


@pytest.mark.chaos
@pytest.mark.slow
@pytest.mark.parametrize("deployment", _DEPLOYMENTS)
def test_serving_chaos_grid(deployment):
    """The full serving chaos grid: 9 seeds x 3 deployments."""
    for seed in _SERVING_CHAOS_SEEDS:
        try:
            _run_serving_chaos(seed, deployment)
        except AssertionError as e:
            raise AssertionError(
                f"serving chaos seed {seed} ({deployment}): {e}") from e


class TestContentionModel:
    """The fitted fan-in contention model and the chunk autotuner it
    drives: exact line recovery, honest extrapolation refusal, and the
    plan threading (``fan_in`` / ``predicted_steps_per_s`` in
    ``explain()``, autotuned chunk replacing the static floor)."""

    def _cells(self, t_base=0.01, k=0.002, fan_ins=(1, 2, 4)):
        from repro.insitu import plan as P
        return [{"fan_in": f, "steps_per_s": 1.0 / (t_base + k * f),
                 "step_bytes": 128.0} for f in fan_ins], P

    def test_fit_recovers_exact_line(self):
        cells, P = self._cells()
        m = P.ContentionModel.fit(cells)
        assert abs(m.t_base - 0.01) < 1e-12
        assert abs(m.k_fanin - 0.002) < 1e-12
        assert m.step_bytes == 128.0
        assert m.residual(cells) < 1e-9
        for c in cells:
            assert abs(m.predict_steps_per_s(c["fan_in"])
                       - c["steps_per_s"]) < 1e-6

    def test_fit_sign_is_measured_not_assumed(self):
        # emulated meshes can run FASTER at higher fan_in (fewer db
        # devices to coordinate) — the slope must come out negative
        cells, P = self._cells(t_base=0.02, k=-0.001)
        m = P.ContentionModel.fit(cells)
        assert m.k_fanin < 0

    def test_fit_needs_two_distinct_points(self):
        cells, P = self._cells(fan_ins=(3, 3))
        with pytest.raises(ValueError, match="distinct fan_in"):
            P.ContentionModel.fit(cells)

    def test_predict_refuses_axis_crossing_extrapolation(self):
        _, P = self._cells()
        m = P.ContentionModel(t_base=0.01, k_fanin=-0.004)
        with pytest.raises(ValueError, match="non-positive"):
            m.predict_steps_per_s(4)    # 0.01 - 0.016 < 0

    def test_autotune_fallbacks_and_floor(self):
        _, P = self._cells()
        # no model: exactly the static default (the old hardcoded floor)
        assert P.autotune_chunk(2) == P.default_chunk(2) \
            == S.MIN_BUCKET * 2
        # extrapolation outside the fitted sweep: same honest fallback
        m = P.ContentionModel(t_base=0.01, k_fanin=-0.004)
        assert P.autotune_chunk(2, m, fan_in=4) == P.default_chunk(2)

    def test_autotune_amortizes_dispatch_cost(self):
        _, P = self._cells()
        cheap = P.ContentionModel(t_base=1e-3, k_fanin=0.0,
                                  t_dispatch=1e-6)
        dear = P.ContentionModel(t_base=1e-3, k_fanin=0.0,
                                 t_dispatch=1.0)
        lo = P.autotune_chunk(1, cheap, steps=72)
        hi = P.autotune_chunk(1, dear, steps=72)
        # costly dispatches push toward longer chunks (fewer captures)
        assert lo < hi <= 512
        # every candidate sits on the compile-cache bucket grid
        for c in (lo, hi):
            assert c == S.bucket_length(c)
        # near-free dispatch: nothing to amortize, stay on the floor
        assert lo == S.bucket_length(S.MIN_BUCKET)

    def test_plan_threads_model_into_explain_and_chunk(self):
        from repro.insitu import plan as P
        m = P.ContentionModel(t_base=1e-3, k_fanin=0.0, t_dispatch=0.05)
        dep = make_clustered_1d()
        dep.cost_model = m
        sess = InSituSession(
            tables=[TableSpec("field", shape=(4, N), capacity=16,
                              engine="ring")],
            components=[Producer(_step, table="field", steps=12, ranks=1,
                                 carry=jnp.zeros(()), emit_every=1)],
            deployment=dep)
        plan = sess.plan()
        entry = plan.component("producer")
        # chunk autotuned from the fitted model, not the static floor —
        # 0.05s/dispatch over 12 steps amortizes into ONE chunk
        assert entry.chunk == P.autotune_chunk(1, m, steps=12,
                                               fan_in=dep.fan_in)
        assert entry.chunk > P.default_chunk(1)
        ex = entry.explain()
        assert ex["fan_in"] == dep.fan_in == 1
        assert ex["predicted_steps_per_s"] \
            == pytest.approx(m.predict_steps_per_s(1))
        # predictions stay exact when the autotuned plan actually runs
        res = sess.run(plan=plan, sequential=True, max_wall_s=240)
        assert res.ok
        stats = res.server.stats()
        assert stats["op_count"] == plan.store_dispatches
        assert stats["staged_transfers"] == plan.staged_transfers
        assert dict(entry.dispatches) == {"capture": 1, "drain": 1}


class TestSlabShardedResolution:
    """Fast (non-slow) tier-rule checks for the new slab-sharded tier."""

    def _cfg(self, **kw):
        return tr.TrainerConfig(ae=_TINY_AE, gather=4, batch_size=2, **kw)

    def test_flag_requires_mesh(self):
        with pytest.raises(ValueError):
            self._cfg(slab_sharded=True)

    def test_resolution_and_override_conflicts(self):
        from repro.insitu import plan as P
        from repro.parallel.sharding import data_mesh
        mesh = data_mesh(1)
        cfg = self._cfg(mesh=mesh, slab_sharded=True)
        assert P.trainer_tier(cfg) == "slab_sharded"
        assert P.trainer_tier(self._cfg(mesh=mesh)) == "sharded_fused"
        with pytest.raises(ValueError):   # flag set, tier would ignore it
            P.trainer_tier(cfg, "sharded_fused")
        with pytest.raises(ValueError):   # tier named, flag unset
            P.trainer_tier(self._cfg(mesh=mesh), "slab_sharded")
        with pytest.raises(ValueError):   # no mesh
            P.trainer_tier(self._cfg(), "slab_sharded")

    def test_clustered_tier_resolution(self):
        """The slab-sharded CLUSTERED tier: resolved when the config
        carries the dedicated db mesh, with override conflicts rejected
        both ways."""
        from repro.insitu import plan as P
        from repro.parallel.sharding import data_mesh
        mesh = data_mesh(1)
        cfg = self._cfg(mesh=mesh, slab_sharded=True, db_mesh=mesh,
                        db_axis="data")
        assert P.trainer_tier(cfg) == "slab_sharded_clustered"
        assert P.trainer_tier(cfg, "slab_sharded_clustered") \
            == "slab_sharded_clustered"
        with pytest.raises(ValueError):   # tier named, db_mesh unset
            P.trainer_tier(self._cfg(mesh=mesh, slab_sharded=True),
                           "slab_sharded_clustered")
        with pytest.raises(ValueError):   # db_mesh set, tier ignores it
            P.trainer_tier(cfg, "slab_sharded")
        with pytest.raises(ValueError):   # db_mesh without slab_sharded
            self._cfg(mesh=mesh, db_mesh=mesh)

    def test_builder_on_degenerate_mesh(self):
        """A 1-device mesh is a valid slab-sharded deployment (laptop
        scale): the builder accepts it and the placement shards the slot
        axis (trivially).  Non-divisible capacity rejection needs a real
        multi-device mesh — covered by the subprocess tests."""
        from repro.parallel.sharding import data_mesh, slab_sharding
        from repro.train import optimizer as opt
        mesh = data_mesh(1)
        cfg = self._cfg(mesh=mesh, slab_sharded=True)
        levels = ae.coords_pyramid(cfg.ae, COORDS)
        spec = TableSpec("f", shape=(4, N), capacity=16)
        tr.EPOCH_BUILDERS["slab_sharded"](cfg, levels, opt.adam(1e-3), spec)
        sh = slab_sharding(spec, mesh)
        assert sh.spec == jax.sharding.PartitionSpec("data", None, None)

    def test_predicted_collectives_in_explain(self):
        from repro.insitu import plan as P
        entry = P.ComponentPlan(
            name="t", kind="trainer", tier="slab_sharded", steps=2,
            predicted_collectives=P.TRAINER_COLLECTIVE_PREDICTIONS[
                "slab_sharded"])
        ex = entry.explain()
        assert ex["predicted_collectives"]["all-reduce"] == "nonzero"
        assert ex["predicted_collectives"]["all-gather"] == "zero"
        # check_collectives flags a measured mismatch
        bad = P.ComponentPlan(
            name="t", kind="trainer", tier="slab_sharded", steps=2,
            predicted_collectives=P.TRAINER_COLLECTIVE_PREDICTIONS[
                "slab_sharded"],
            collectives=(("all-reduce", 3), ("all-gather", 1)))
        with pytest.raises(AssertionError):
            bad.check_collectives()


# ---------------------------------------------------------------------------
# The element-sharded producer tier (capture_scan_sharded)
# ---------------------------------------------------------------------------

class TestShardedProducerResolution:
    """Fast tier-rule checks for ``capture_scan_sharded``."""

    def _comp(self, **kw):
        from repro.parallel.sharding import space_mesh
        from repro.sim import distributed as fd
        cfg = fd.FDConfig(n=8, jacobi_iters=4)
        step_fn, s0, es = fd.make_producer(cfg, space_mesh(1))
        kw.setdefault("elem_sharding", es)
        kw.setdefault("carry", s0)
        return Producer(step_fn, table="field", steps=4, **kw), es

    def test_resolution(self):
        from repro.insitu import plan as P
        comp, _ = self._comp()
        assert P.producer_tier(comp) == "capture_scan_sharded"
        # elem_sharding=None falls back to plain capture_scan
        comp2, _ = self._comp(elem_sharding=None)
        assert P.producer_tier(comp2) == "capture_scan"

    def test_forced_tier_conflicts(self):
        from repro.insitu import plan as P
        comp, es = self._comp(tier="capture_scan_sharded")
        assert P.producer_tier(comp) == "capture_scan_sharded"
        # per_verb stays forceable (the unfused baseline)
        assert P.producer_tier(self._comp(tier="per_verb")[0]) == "per_verb"
        with pytest.raises(ValueError, match="drop the declared"):
            P.producer_tier(self._comp(tier="capture_scan")[0])
        with pytest.raises(ValueError, match="needs elem_sharding"):
            P.producer_tier(Producer(_step, table="field", steps=4,
                                     carry=jnp.zeros(()),
                                     tier="capture_scan_sharded"))
        with pytest.raises(ValueError, match="single-rank"):
            P.producer_tier(self._comp(ranks=2)[0])
        with pytest.raises(ValueError, match="traceable"):
            P.producer_tier(self._comp(traceable=False)[0])

    def test_collective_prediction_rule(self):
        """The ppermute-only claim is made exactly where it is
        structural: co-located, genuinely sharded, > 1 device."""
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.insitu import plan as P
        from repro.parallel.sharding import space_mesh
        es1 = NamedSharding(space_mesh(1), PartitionSpec(None, "space"))
        assert P.sharded_producer_prediction(es1, colocated=True) is None
        assert P.sharded_producer_prediction(None, colocated=True) is None
        assert P.sharded_producer_prediction(es1, colocated=False) is None
        # >1-device shape needs a forced device count — structural check
        # of the returned tuple shape via the 1-device degenerate instead:
        pred = P._pred(collective_permute=True)
        assert dict(pred)["collective-permute"] is True
        assert dict(pred)["all-gather"] is False


class TestShardedProducerExactness:
    """plan.explain() dispatch + staged predictions for the
    element-sharded producer tier equal ``stats()`` exactly across the
    {local, colocated, clustered, clustered-2d} deployment cells (the
    acceptance criterion; multi-shard cells run in the slow subprocess
    test below)."""

    @pytest.fixture(scope="class")
    def producer(self):
        from repro.parallel.sharding import space_mesh
        from repro.sim import distributed as fd
        cfg = fd.FDConfig(n=8, jacobi_iters=8)
        return fd.make_producer(cfg, space_mesh(1)), cfg

    def _deployment(self, kind):
        from jax.sharding import PartitionSpec as PS
        from repro.core.deployment import (Colocated, make_clustered_1d,
                                           make_clustered_2d)
        from repro.parallel.sharding import space_mesh
        spec = PS(None, "space", None)
        if kind == "none":
            return None
        if kind == "colocated":
            return Colocated(mesh=space_mesh(1), elem_spec=spec)
        if kind == "clustered":
            return make_clustered_1d(axis="space", elem_spec=spec)
        return make_clustered_2d(spec)

    @pytest.mark.parametrize("deployment", ("none", "colocated",
                                            "clustered", "clustered_2d"))
    def test_exact_predictions(self, producer, deployment):
        (step_fn, s0, es), cfg = producer
        sess = InSituSession(
            tables=[TableSpec("field", shape=(2, cfg.n, cfg.n),
                              capacity=16)],
            components=[Producer(step_fn, table="field", steps=12, chunk=4,
                                 carry=s0, elem_sharding=es)],
            deployment=self._deployment(deployment))
        plan = sess.plan()
        entry = plan.component("producer")
        assert entry.tier == "capture_scan_sharded"
        res = sess.run(plan=plan, sequential=True, max_wall_s=240)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        stats = res.server.stats()
        clustered = deployment in ("clustered", "clustered_2d")
        # ceil(12 / 4) captures; the overlapped clustered cells pay one
        # extra capture-end drain dispatch to flush the pipeline
        expect_ops = 4 if clustered else 3
        assert stats["op_count"] == plan.store_dispatches \
            == entry.store_dispatches == expect_ops
        assert stats["staged_transfers"] == plan.staged_transfers
        if clustered:
            # ONE hop per chunk — the staged/chunk invariant; the drain
            # inserts without re-staging, so it must not dilute the ratio
            assert entry.staged == (("chunk_stage", 3),)
            assert dict(entry.dispatches) == {"capture": 3, "drain": 1}
            assert entry.explain()["staged_per_chunk"] == 1.0
            assert entry.fan_in == res.server.deployment.fan_in
        else:
            assert plan.staged_transfers == 0
            assert entry.fan_in == 1
        assert res.server.watermark("field") == 12 \
            == res.server.watermark_device("field")

    def test_2d_db_mesh_lifts_disjoint_axes(self, producer):
        """The 2-D (slab, element) db mesh carries a slot partition AND
        an element partition at once — the combination a 1-D db mesh
        must reject."""
        from jax.sharding import PartitionSpec as PS
        from repro.core.deployment import (Clustered, make_clustered_1d,
                                           make_clustered_2d)
        dep = make_clustered_2d(PS(None, "space", None))
        assert dep.slab_axis == "slab"
        assert set(dep.db_mesh.axis_names) == {"slab", "space"}
        spec = TableSpec("field", shape=(2, 8, 8), capacity=16)
        sh = dep.slab_sharding(spec)
        # slot axis on "slab", element dim 1 on "space", in one sharding
        assert sh.spec[1:] == (None, "space", None)
        with pytest.raises(ValueError, match="disjoint"):
            make_clustered_1d(axis="space", elem_spec=PS(None, "space"),
                              slab_axis="space")
        with pytest.raises(ValueError, match="own axes"):
            make_clustered_2d(PS(None, "slab", None))

    def test_faults_route_through_logged_path(self, producer):
        """An armed FaultPlan moves the sharded tier onto the logged
        collect -> masked-insert path; retry predictions stay exact."""
        from repro.core.faults import FaultPlan, RetryPolicy
        (step_fn, s0, es), cfg = producer
        faults = FaultPlan(events=(FaultEvent(
            "drop_chunk", table="field", at=1),),
            retry=RetryPolicy(seed=7, **_FAST_RETRY))
        sess = InSituSession(
            tables=[TableSpec("field", shape=(2, cfg.n, cfg.n),
                              capacity=16)],
            components=[Producer(step_fn, table="field", steps=12, chunk=4,
                                 carry=s0, elem_sharding=es)],
            faults=faults)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, max_wall_s=240)
        assert res.ok
        entry = plan.component("producer")
        assert res.op_delta("producer") == entry.store_dispatches
        assert res.server.watermark("field") == 12


@pytest.mark.slow
class TestShardedProducerMultiDevice:
    def test_colocated_hlo_claim_and_exactness(self):
        """2 space shards, co-located: the compiled sharded chunk's ONLY
        collective is the halo ppermute (all-gather zero — the put stays
        shard-local), predictions exact, and the stored snapshots match
        the single-device reference solver bit-for-bit gathered."""
        from conftest import run_subprocess
        run_subprocess("""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as PS
            from repro.core import TableSpec
            from repro.core.deployment import Colocated
            from repro.insitu import InSituSession, Producer
            from repro.parallel.sharding import space_mesh
            from repro.sim import distributed as fd

            mesh = space_mesh(2)
            cfg = fd.FDConfig(n=16, jacobi_iters=8)
            step_fn, s0, es = fd.make_producer(cfg, mesh)
            sess = InSituSession(
                tables=[TableSpec("field", shape=(2, cfg.n, cfg.n),
                                  capacity=16)],
                components=[Producer(step_fn, table="field", steps=12,
                                     chunk=4, carry=s0,
                                     elem_sharding=es)],
                deployment=Colocated(mesh=mesh,
                                     elem_spec=PS(None, "space", None)))
            plan = sess.plan(hlo=True)
            entry = plan.component("producer")
            assert entry.tier == "capture_scan_sharded"
            m = dict(entry.collectives)
            assert m["collective-permute"] > 0, m
            assert m["all-gather"] == 0 and m["all-reduce"] == 0, m
            entry.check_collectives()    # prediction matches measurement

            res = sess.run(plan=plan, sequential=True, max_wall_s=240)
            assert res.ok
            stats = res.server.stats()
            assert stats["op_count"] == plan.store_dispatches == 3
            assert stats["staged_transfers"] == 0
            assert res.server.watermark("field") == 12

            # content parity: the last stored snapshot equals the
            # single-device reference advanced the same 12 steps
            ref_step = fd.make_step(cfg)
            r = fd.taylor_green(cfg)
            for _ in range(12):
                r = ref_step(r)
            st = res.server.checkout("field")
            from repro.core import store as S
            val, ok = S.get(TableSpec("field", shape=(2, cfg.n, cfg.n),
                                      capacity=16), st,
                            S.make_key(0, 11))
            assert bool(ok)
            np.testing.assert_allclose(
                np.asarray(val),
                np.asarray(jnp.stack([r.u, r.v])), atol=1e-5)
            print("OK")
        """, n_devices=2)
