"""The clustered data plane, core level: masked-put / collect-scan
equivalence with the in-scan capture tiers, staged-transfer telemetry,
spec-threaded element staging, `split_devices` / fan-in edge cases, and
the poll-loop backoff deadline clamp.

Session-level clustered scenarios (plans, staged predictions, the
slab-sharded clustered tier) live in ``tests/test_session.py`` and
``tests/test_plan_properties.py``; the real split-mesh runs are
subprocess tests there."""

import textwrap
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess

from repro.core import (Client, Clustered, Colocated, StoreServer,
                        TableSpec, make_clustered_1d, split_devices)
from repro.core import store as S

SPEC = TableSpec("t", shape=(3,), capacity=4, engine="ring")


def _step(c, t):
    return c + 1.0, S.make_key(0, t), jnp.full((3,), t, jnp.float32)


def _step_multi(c, r, t):
    return c + 1.0, S.make_key(r, t), jnp.full((3,), t * 10 + r,
                                               jnp.float32)


def _assert_states_equal(a: S.TableState, b: S.TableState):
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestPutMasked:
    """put_masked == replaying the masked elements' per-verb puts."""

    def test_ring_matches_sequential_puts(self):
        keys = jnp.asarray([3, 7, 11, 15, 19, 23], jnp.uint32)
        vals = jnp.arange(18, dtype=jnp.float32).reshape(6, 3)
        mask = jnp.asarray([True, False, True, True, False, True])
        ref = S.init_table(SPEC)
        for k, v, m in zip(keys, vals, mask):
            if bool(m):
                ref = S.put(SPEC, ref, k, v)
        got = S.put_masked(SPEC, S.init_table(SPEC), keys, vals, mask)
        _assert_states_equal(ref, got)
        assert int(got.count) == 4

    def test_ring_wraparound_last_writer_wins(self):
        """More masked elements than capacity: ring wrap, every overwrite
        still bumps count — byte-identical to sequential replay."""
        n = 11   # > 2 * capacity
        keys = jnp.arange(1, n + 1, dtype=jnp.uint32)
        vals = jnp.arange(3 * n, dtype=jnp.float32).reshape(n, 3)
        mask = jnp.ones((n,), bool).at[4].set(False)
        ref = S.init_table(SPEC)
        for k, v, m in zip(keys, vals, mask):
            if bool(m):
                ref = S.put(SPEC, ref, k, v)
        got = S.put_masked(SPEC, S.init_table(SPEC), keys, vals, mask)
        _assert_states_equal(ref, got)
        assert int(got.count) == n - 1

    def test_hash_collisions_match_put_many(self):
        hspec = TableSpec("h", shape=(2,), capacity=4, engine="hash")
        keys = jnp.asarray([1, 5, 2, 9, 13], jnp.uint32)  # 1≡5≡9≡13 mod 4
        vals = jnp.arange(10, dtype=jnp.float32).reshape(5, 2)
        mask = jnp.asarray([True, True, False, True, True])
        ref = S.init_table(hspec)
        for k, v, m in zip(keys, vals, mask):
            if bool(m):
                ref = S.put_many(hspec, ref, k[None], v[None])
        got = S.put_masked(hspec, S.init_table(hspec), keys, vals, mask)
        _assert_states_equal(ref, got)

    def test_empty_mask_is_noop(self):
        keys = jnp.asarray([1, 2], jnp.uint32)
        vals = jnp.zeros((2, 3))
        st0 = S.init_table(SPEC)
        got = S.put_masked(SPEC, jax.tree.map(jnp.copy, st0), keys, vals,
                           jnp.zeros((2,), bool))
        _assert_states_equal(st0, got)
        assert int(got.count) == 0


class TestCaptureCollect:
    """collect + put_masked == the in-scan capture_scan tiers."""

    def test_single_rank_equivalence(self):
        ref, c_ref = S.capture_scan(SPEC, S.init_table(SPEC), _step,
                                    jnp.zeros(()), 10, 2, t0=0)
        c, keys, vals, mask = S.capture_scan_collect(
            SPEC, _step, jnp.zeros(()), 10, 2, t0=0)
        got = S.put_masked(SPEC, S.init_table(SPEC), keys, vals, mask)
        _assert_states_equal(ref, got)
        assert float(c) == float(c_ref)

    def test_multi_rank_equivalence(self):
        ref, _ = S.capture_scan_multi(SPEC, S.init_table(SPEC),
                                      _step_multi, jnp.zeros((3,)), 7, 3,
                                      2, t0=0)
        _, keys, vals, mask = S.capture_scan_collect_multi(
            SPEC, _step_multi, jnp.zeros((3,)), 7, 3, 2, t0=0)
        got = S.put_masked(SPEC, S.init_table(SPEC), keys, vals, mask)
        _assert_states_equal(ref, got)
        assert int(got.count) == 3 * 4   # ranks * emits

    def test_compact_payload_scales_with_emissions(self):
        """A sparse emit_every must not ship zero rows across the
        interconnect: the collected buffer holds capture_rows(length,
        emit_every) rows, not one per step."""
        _, keys, vals, mask = S.capture_scan_collect(
            SPEC, _step, jnp.zeros(()), 32, 8, t0=0)
        assert vals.shape[0] == keys.shape[0] == S.capture_rows(32, 8) == 4
        assert int(jnp.sum(mask)) == 4
        # multi form: rows * ranks, rank-major
        _, keys, vals, mask = S.capture_scan_collect_multi(
            SPEC, _step_multi, jnp.zeros((3,)), 32, 3, 8, t0=0)
        assert vals.shape[0] == 4 * 3

    def test_bucketed_tail_and_traced_t0(self):
        """valid masking (bucketed tails) + traced t0 chunk clocks."""
        t0, valid = jnp.asarray(3), jnp.asarray(5)
        ref, c_ref = S.capture_scan(SPEC, S.init_table(SPEC), _step,
                                    jnp.zeros(()), 8, 2, t0=t0,
                                    valid=valid)
        c, keys, vals, mask = S.capture_scan_collect(
            SPEC, _step, jnp.zeros(()), 8, 2, t0=t0, valid=valid)
        got = S.put_masked(SPEC, S.init_table(SPEC), keys, vals, mask)
        _assert_states_equal(ref, got)
        assert float(c) == float(c_ref)     # dead steps advance nothing
        assert int(jnp.sum(mask)) == 2       # t in {4, 6}


class TestStagedTelemetry:
    """stats()['staged_transfers'] counts exactly the interconnect hops."""

    def _clustered_server(self):
        srv = StoreServer(make_clustered_1d())   # degenerate shared device
        srv.create_table(TableSpec("t", shape=(3,), capacity=8))
        return srv

    def test_fused_chunk_stages_once(self):
        srv = self._clustered_server()
        cli = Client(srv)
        cli.capture_scan("t", _step, jnp.zeros(()), 10, emit_every=2)
        st = srv.stats()
        assert st["staged_transfers"] == 1      # ONE hop for 5 puts
        # overlap holds the (sole) chunk in the pipeline: the hop is paid
        # but the insert waits for the drain at end-of-capture.
        cli.drain_captures("t")
        st = srv.stats()
        assert st["staged_transfers"] == 1      # drain inserts, never stages
        assert st["op_count"] == 2              # capture + drain flush
        assert srv.watermark("t") == 5 == srv.watermark_device("t")

    def test_fused_chunk_equals_colocated_replay(self):
        srv = self._clustered_server()
        cli = Client(srv)
        cli.capture_scan("t", _step, jnp.zeros(()), 10, emit_every=2)
        cli.drain_captures("t")
        srv2 = StoreServer()
        srv2.create_table(TableSpec("t", shape=(3,), capacity=8))
        Client(srv2).capture_scan("t", _step, jnp.zeros(()), 10,
                                  emit_every=2)
        _assert_states_equal(srv.checkout("t"), srv2.checkout("t"))

    def test_per_verb_stages_per_element(self):
        srv = self._clustered_server()
        for t in range(3):
            srv.put("t", S.make_key(0, t), jnp.ones((3,)))
        assert srv.stats()["staged_transfers"] == 3

    def test_batched_verbs_stage_once(self):
        srv = self._clustered_server()
        srv.put_many("t", jnp.arange(4, dtype=jnp.uint32),
                     jnp.ones((4, 3)))
        assert srv.stats()["staged_transfers"] == 1
        srv.put_stream("t", jnp.arange(6, dtype=jnp.uint32).reshape(3, 2),
                       jnp.ones((3, 2, 3)))
        assert srv.stats()["staged_transfers"] == 2

    def test_sample_staged_counts_one(self):
        srv = self._clustered_server()
        srv.put("t", S.make_key(0, 0), jnp.ones((3,)))
        before = srv.stats()
        vals, ok = srv.sample_staged("t", jax.random.key(0), 4)
        after = srv.stats()
        assert vals.shape == (4, 3) and bool(ok)
        assert after["staged_transfers"] == before["staged_transfers"] + 1
        assert after["op_count"] == before["op_count"] + 1

    def test_colocated_and_local_never_stage(self):
        for dep in (None, Colocated(jax.make_mesh((1,), ("data",)))):
            srv = StoreServer(dep)
            srv.create_table(TableSpec("t", shape=(3,), capacity=8))
            srv.put("t", S.make_key(0, 0), jnp.ones((3,)))
            Client(srv).capture_scan("t", _step, jnp.zeros(()), 4)
            srv.sample_staged("t", jax.random.key(0), 2)
            assert srv.stats()["staged_transfers"] == 0


class TestDeploymentEdges:
    def test_split_devices_extreme_fractions(self):
        devs = list(range(8))     # split_devices only slices the list
        clients, db = split_devices(devs, db_fraction=0.0)
        assert db == [7] and clients == devs[:7]   # at least one db device
        clients, db = split_devices(devs, db_fraction=1.0)
        assert clients == [0] and db == devs[1:]   # at least one client
        clients, db = split_devices([42], db_fraction=0.5)
        assert clients == db == [42]               # degenerate shared

    def test_fan_in_ceiling_division(self):
        """fan_in is the BUSIEST shard's client count — ceiling division
        (the old floor quietly reported 1 for 3 clients on 2 shards),
        flooring at 1 when clients < db shards.  The plan's
        ``ComponentPlan.fan_in`` must agree with the deployment on every
        non-divisible split because both call ``fan_in_ratio``."""
        from repro.core.deployment import fan_in_ratio
        from repro.insitu import plan as P
        def fake_mesh(n):
            return SimpleNamespace(shape={"data": n})
        dep = Clustered.__new__(Clustered)
        dep.elem_spec = ()
        dep.slab_axis = None
        for clients, db, expect in [(1, 3, 1), (3, 1, 3), (3, 2, 2),
                                    (7, 2, 4), (4, 4, 1), (5, 3, 2)]:
            dep.client_mesh = fake_mesh(clients)
            dep.db_mesh = fake_mesh(db)
            dep.__post_init__()
            assert dep.fan_in == expect, (clients, db, dep.fan_in)
            # plan == deployment: one ceiling-division source for both
            assert P.fan_in_ratio(clients, db) == dep.fan_in
        assert P.fan_in_ratio is fan_in_ratio

    def test_deployment_star_exports_helpers(self):
        """Regression: ``make_colocated_1d`` was missing from __all__ —
        invisible to star imports and check_docs dotted-ref resolution."""
        from repro.core import deployment as D
        assert "make_colocated_1d" in D.__all__
        assert "make_clustered_1d" in D.__all__
        ns = {}
        exec("from repro.core.deployment import *", ns)
        assert callable(ns["make_colocated_1d"])

    def test_elem_spec_threaded_through_staging(self):
        """Regression: ``Clustered.stage`` discarded the table spec
        (``elem_sharding(None)``), so spec-dependent layouts never
        applied.  The staged element must land with the spec-fitted
        element sharding."""
        from jax.sharding import PartitionSpec as P
        dep = make_clustered_1d(elem_spec=P("data", None))
        srv = StoreServer(dep)
        spec = srv.create_table(TableSpec("t", shape=(4, 6), capacity=4))
        srv.put("t", S.make_key(0, 0), jnp.ones((4, 6)))
        v, found = srv.get("t", S.make_key(0, 0))
        assert bool(found)
        assert dep.elem_sharding(spec).spec == P("data", None)
        # non-divisible element dim falls back to replicated, not an error
        spec3 = TableSpec("odd", shape=(3, 6), capacity=4)
        fitted = dep.elem_sharding(spec3)
        assert fitted.mesh is dep.db_mesh
        staged = dep.stage(jnp.ones((3, 6)), spec3)
        assert staged.shape == (3, 6)
        # an elem_spec LONGER than the element rank stays loud
        with pytest.raises(ValueError):
            dep.elem_sharding(TableSpec("r1", shape=(4,), capacity=4))


class TestBackoffDeadlines:
    """Satellite: exponential backoff must clamp its sleeps to the
    remaining budget instead of overshooting ``timeout`` by up to
    ``max_interval``."""

    def test_wait_watermark_never_overshoots(self):
        srv = StoreServer()
        srv.create_table(TableSpec("t", shape=(2,), capacity=4))
        t0 = time.perf_counter()
        ok = srv.wait_watermark("t", 1, timeout=0.15, interval=0.001,
                                max_interval=10.0, strict=False)
        took = time.perf_counter() - t0
        assert not ok
        # without the clamp the doubling backoff sleeps past the deadline
        # by seconds; with it the call returns at ~timeout
        assert took < 0.15 + 0.1, took

    def test_poll_tensor_never_overshoots(self):
        srv = StoreServer()
        srv.create_table(TableSpec("t", shape=(2,), capacity=4))
        client = Client(srv)
        t0 = time.perf_counter()
        ok = client.poll_tensor("missing", table="t", timeout=0.15,
                                interval=0.001, max_interval=10.0,
                                strict=False)
        took = time.perf_counter() - t0
        assert not ok
        assert took < 0.15 + 0.25, took   # polls dispatch device ops

    def test_wait_watermark_still_succeeds_late(self):
        srv = StoreServer()
        srv.create_table(TableSpec("t", shape=(2,), capacity=4))
        import threading

        def put_later():
            time.sleep(0.05)
            srv.put("t", S.make_key(0, 0), jnp.zeros((2,)))

        threading.Thread(target=put_later, daemon=True).start()
        assert srv.wait_watermark("t", 1, timeout=5.0)


class TestOverlapPipeline:
    """Double-buffered staging (chunk N's reshard overlapped with chunk
    N+1's collect-scan) must be byte-identical to serial staging across
    {divisible, masked-tail} captures x {ring wrap, no wrap} x chaos
    restage — same table leaves, same watermark, same staged hops; the
    pipeline only adds drain dispatches, never data differences."""

    def _run(self, overlap, *events, capacity=16, length=8, emit_every=2,
             n_chunks=3):
        from repro.core.faults import FaultEvent, FaultPlan, RetryPolicy
        plan = FaultPlan(events=tuple(events),
                         retry=RetryPolicy(interval=1e-4,
                                           max_interval=1e-3))
        srv = StoreServer(make_clustered_1d(overlap=overlap), faults=plan)
        srv.create_table(TableSpec("t", shape=(3,), capacity=capacity))
        cli = Client(srv)
        for i in range(n_chunks):
            cli.capture_scan("t", _step, jnp.zeros(()), length,
                             emit_every=emit_every, t0=i * length)
        cli.drain_captures("t")
        return srv, cli

    def _assert_parity(self, **kw):
        ov_srv, ov_cli = self._run(True, **kw)
        se_srv, se_cli = self._run(False, **kw)
        assert ov_srv.watermark("t") == se_srv.watermark("t")
        _assert_states_equal(ov_srv.checkout("t"), se_srv.checkout("t"))
        ov, se = ov_srv.stats(), se_srv.stats()
        # one hop per wire crossing, identically in both schedules
        assert ov["staged_transfers"] == se["staged_transfers"]
        return ov_srv, se_srv, ov_cli, se_cli

    def test_divisible_no_wrap(self):
        # 3 chunks x 4 puts, capacity 16: exact buckets, no ring wrap
        ov, se, *_ = self._assert_parity(capacity=16, length=8,
                                         emit_every=2)
        assert ov.watermark("t") == 12
        assert ov.stats()["staged_transfers"] == 3
        # overlap costs exactly the end-of-capture drain flush
        assert ov.stats()["op_count"] == se.stats()["op_count"] + 1

    def test_masked_tail_no_wrap(self):
        # length 7, emit_every 2 -> 4 live rows + a masked bucket tail
        ov, *_ = self._assert_parity(capacity=16, length=7, emit_every=2)
        assert ov.watermark("t") == 11

    def test_divisible_ring_wrap(self):
        # 12 puts into capacity 4: wraps twice, last writer wins
        ov, *_ = self._assert_parity(capacity=4, length=8, emit_every=2)
        assert ov.watermark("t") == 12
        assert int(ov.checkout("t").count) == 12

    def test_masked_tail_ring_wrap(self):
        ov, *_ = self._assert_parity(capacity=4, length=7, emit_every=2)
        assert ov.watermark("t") == 11

    def test_chaos_restage_parity(self):
        """A dropped transfer mid-pipeline forces the drain-on-restage
        flush; a later duplicate is deduped by the ack set.  Both
        schedules retry under the same chunk id and land byte-identical
        to each other and to the fault-free run."""
        from repro.core.faults import FaultEvent
        events = (FaultEvent("drop_chunk", table="t", at=1),
                  FaultEvent("dup_chunk", table="t", at=3))
        ov, se, ov_cli, se_cli = self._assert_parity(capacity=8, length=8,
                                                     emit_every=2,
                                                     n_chunks=3)
        base_wm = ov.watermark("t")
        ov_srv, ov_cli2 = self._run(True, *events, capacity=8)
        se_srv, se_cli2 = self._run(False, *events, capacity=8)
        assert ov_cli2.retries == 1 == se_cli2.retries
        assert ov_srv.stats()["faults_injected"] == 2
        assert ov_srv.watermark("t") == se_srv.watermark("t") == base_wm
        _assert_states_equal(ov_srv.checkout("t"), se_srv.checkout("t"))
        _assert_states_equal(ov_srv.checkout("t"), ov.checkout("t"))
        # drop pays its hop again on retry, dup pays one extra: +2 hops,
        # identically in both schedules
        assert ov_srv.stats()["staged_transfers"] == 5
        assert se_srv.stats()["staged_transfers"] == 5


@pytest.mark.slow
def test_clustered_core_real_split_mesh():
    """The core clustered mechanics on a REAL 4-device split (2 clients +
    2 db): the staged chunk equals the co-located replay byte-for-byte,
    staged transfers count one per chunk, the element layout honors the
    fitted ``elem_spec``, and the slot-partitioned slab lives only on the
    db devices."""
    run_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import (Client, StoreServer, TableSpec,
                                make_clustered_1d)
        from repro.core import store as S

        def step(c, t):
            return c + 1.0, S.make_key(0, t), \\
                jnp.arange(8, dtype=jnp.float32) * (t + 1.0)

        # slab_axis colliding with an elem_spec axis is rejected (a
        # partitioned slot lives whole on its shard)
        try:
            make_clustered_1d(db_fraction=0.5, elem_spec=P("data"),
                              slab_axis="data")
            raise SystemExit("collision not rejected")
        except ValueError:
            pass

        # 2 clients : 2 db, slab slot-partitioned over the db mesh
        dep = make_clustered_1d(db_fraction=0.5, slab_axis="data")
        assert dep.fan_in == 1
        srv = StoreServer(dep)
        spec = srv.create_table(TableSpec("t", shape=(8,), capacity=8))

        # placement: slab slot-partitioned on the two db devices only
        slab = srv.checkout("t").slab
        devs = sorted(d.id for s in slab.addressable_shards
                      for d in [s.device])
        db_ids = sorted(d.id for d in dep.db_mesh.devices.ravel())
        assert sorted(set(devs)) == db_ids, (devs, db_ids)

        # fused chunk: ONE staged hop, byte-identical to local replay.
        # Overlap parks the chunk in the two-slot pipeline; draining
        # flushes it in one extra store op without re-staging.
        cli = Client(srv)
        cli.capture_scan("t", step, jnp.zeros(()), 10, emit_every=2)
        cli.drain_captures("t")
        st = srv.stats()
        assert st["staged_transfers"] == 1 and st["op_count"] == 2
        srv2 = StoreServer()
        srv2.create_table(TableSpec("t", shape=(8,), capacity=8))
        Client(srv2).capture_scan("t", step, jnp.zeros(()), 10,
                                  emit_every=2)
        for a, b in zip(srv.checkout("t"), srv2.checkout("t")):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # per-verb element staging counts its hop
        srv.put("t", S.make_key(0, 99), jnp.ones((8,)))
        assert srv.stats()["staged_transfers"] == 2

        # element-sharded layout (no slot partitioning): staged elements
        # land sharded across the db devices; non-divisible dims fit back
        # to replicated instead of mis-placing
        dep2 = make_clustered_1d(db_fraction=0.5, elem_spec=P("data"))
        spec8 = TableSpec("e", shape=(8,), capacity=4)
        staged = dep2.stage(jnp.ones((8,)), spec8)
        assert len({s.device.id for s in staged.addressable_shards}) == 2
        assert max(s.data.nbytes for s in staged.addressable_shards) \\
            == staged.nbytes // 2
        assert dep2.elem_sharding(TableSpec("o", shape=(3,), capacity=4)
                                  ).spec == P(None)

        # staged gather: assembled on the db mesh, returned to clients
        vals, ok = srv.sample_staged("t", jax.random.key(0), 4)
        assert bool(ok) and vals.shape == (4, 8)
        vdevs = {d.id for s in vals.addressable_shards
                 for d in [s.device]}
        client_ids = {d.id for d in dep.client_mesh.devices.ravel()}
        assert vdevs <= client_ids, (vdevs, client_ids)
        assert srv.stats()["staged_transfers"] == 3
        print("CLUSTERED_CORE_OK")
    """), n_devices=4, timeout=600.0)
