"""End-to-end behaviour of the in-situ coupling system (the paper's §4
workflow at laptop scale) + fault-tolerance properties."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Client, Colocated, InSituDriver, StoreServer,
                        StragglerPolicy, TableSpec)
from repro.ml import autoencoder as ae
from repro.ml import trainer as tr
from repro.sim import flatplate as fp


FCFG = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
N = FCFG.n_points


def _driver(capacity=16):
    return InSituDriver(tables=[TableSpec("field", shape=(4, N),
                                          capacity=capacity, engine="ring")])


def _producer(n_steps=30, sleep=0.005):
    def fn(client, stop):
        key = jax.random.key(0)
        done = 0
        for step in range(n_steps):
            if stop.is_set():
                break
            snap = fp.snapshot(FCFG, key, step)
            client.send_step("field", step, snap)
            done += 1
            time.sleep(sleep)
        return done
    return fn


def _consumer(epochs=8):
    def fn(client, stop):
        coords = fp.grid_coords(FCFG)
        cfg = tr.TrainerConfig(
            ae=ae.AEConfig(n_points=N, mode="ref", latent=16, mlp_width=16),
            epochs=epochs, gather=6, batch_size=4, lr=1e-3)
        state, history, levels, stats = tr.insitu_train(
            client, coords, cfg, stop_event=stop)
        assert history, "no epochs completed"
        import numpy as _np
        head = _np.mean([h.train_loss for h in history[:2]])
        tail = _np.mean([h.train_loss for h in history[-2:]])
        assert tail < head, \
            f"training loss did not decrease in situ ({head} -> {tail})"
        # register the encoder for the inference phase
        client.set_model("encoder",
                         lambda p, f: ae.encode(p, cfg.ae, levels, f),
                         state.params)
        return len(history)
    return fn


@pytest.mark.slow
def test_insitu_training_end_to_end():
    """Producer and consumer run concurrently, coupled only by the store;
    training converges; component timers land in the paper's buckets."""
    driver = _driver()
    res = driver.run({"sim": _producer(), "ml": _consumer()}, max_wall_s=300)
    assert res.ok, {k: v.error for k, v in res.components.items()}
    assert res.components["sim"].steps == 30
    assert res.components["ml"].steps == 8
    summary = res.timers.summary()
    for bucket in ("client_init", "send", "retrieve", "train"):
        assert bucket in summary, bucket
    # paper claim at this scale: send overhead is far below compute+train
    assert summary["send"]["total_s"] < summary["train"]["total_s"]

    # ---- in-situ inference with the trained model (3-step protocol) ------
    client = driver.client(rank=99)
    assert driver.server.has_model("encoder")
    mu, sd = client.get_metadata("norm_stats")
    snap = fp.snapshot(FCFG, jax.random.key(0), 100)
    x = (snap.T[None] - mu) / sd
    z = client.infer("encoder", x)
    assert z.shape == (1, 16) and bool(jnp.isfinite(z).all())


def test_consumer_never_blocks_on_dead_producer():
    """Straggler/fault tolerance: producer dies after 2 sends — consumer
    still completes its epochs on stale data instead of deadlocking."""
    driver = _driver()

    def dying_producer(client, stop):
        for step in range(2):
            client.send_step("field", step, fp.snapshot(FCFG,
                                                        jax.random.key(0),
                                                        step))
        raise RuntimeError("simulated node failure")

    # stop_on_error=False keeps the fully-loose coupling under test here:
    # the consumer deliberately finishes on stale data after the producer
    # died (the default now fires a prompt shutdown instead).
    res = driver.run({"sim": dying_producer, "ml": _consumer(epochs=3)},
                     max_wall_s=240, stop_on_error=False)
    assert not res.components["sim"].ok
    assert res.components["sim"].error_type == "RuntimeError"
    assert res.failed is None
    assert res.components["ml"].ok, res.components["ml"].error
    assert res.components["ml"].steps == 3


def test_failure_isolation_consumer_crash():
    driver = _driver()

    def bad_consumer(client, stop):
        raise ValueError("simulated OOM")

    res = driver.run({"sim": _producer(n_steps=5), "ml": bad_consumer},
                     max_wall_s=120)
    assert res.components["sim"].ok
    assert not res.components["ml"].ok
    assert "simulated OOM" in res.components["ml"].error
    # the typed taxonomy + prompt-shutdown attribution survive the format
    assert res.components["ml"].error_type == "ValueError"
    assert res.failed == "ml"


def test_three_step_inference_protocol():
    """put_tensor → run_model → get_tensor, each one client call (paper)."""
    server = StoreServer()
    server.create_table(TableSpec("infer_in", shape=(4,), capacity=4,
                                  engine="hash"))
    server.create_table(TableSpec("infer_out", shape=(2,), capacity=4,
                                  engine="hash"))
    client = Client(server)
    client.set_model("head", lambda p, x: x @ p["w"],
                     {"w": jnp.ones((4, 2))})
    client.put_tensor("x", jnp.arange(4.0), table="infer_in")
    client.run_model("head", inputs=["x"], outputs=["y"],
                     table="infer_in", out_table="infer_out")
    y, found = client.get_tensor("y", table="infer_out")
    assert bool(found)
    np.testing.assert_allclose(np.asarray(y), [6.0, 6.0])
    # all three components timed (paper Fig. 7 buckets)
    s = client.timers.summary()
    assert {"send", "model_eval", "retrieve"} <= set(s)


def test_in_memory_checkpoint_restart():
    """The store doubles as an in-RAM checkpoint: a 'failed' trainer
    restarts from the parked state without touching the filesystem."""
    from repro.train.checkpoint import MemoryCheckpoint
    server = StoreServer()
    mc = MemoryCheckpoint(server)
    state = {"w": jnp.arange(3.0), "step": jnp.int32(7)}
    mc.save(7, state)
    got = mc.restore()
    assert got is not None
    step, restored = got
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["w"]), [0, 1, 2])
