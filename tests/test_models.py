"""Model zoo: per-arch smoke tests + component-level correctness.

Every assigned architecture instantiates its REDUCED (same-family) config
and runs one forward/train step on CPU asserting output shapes and no
NaNs; decode is checked against the teacher-forced forward logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm, moe, ssd, whisper
from repro.models.config import ModelConfig
from repro.models.layers import rope
from repro.parallel.sharding import init_params

B, S = 2, 32


def _lm_setup(cfg, seed=0):
    params = init_params(jax.random.key(seed), lm.lm_specs(cfg), cfg.dtype)
    tokens = jax.random.randint(jax.random.key(seed + 1), (B, S), 0,
                                cfg.vocab)
    extra, labels = None, tokens
    if cfg.frontend == "vision":
        extra = jax.random.normal(
            jax.random.key(seed + 2),
            (B, cfg.frontend_tokens, cfg.d_model)) * 0.1
        labels = jnp.concatenate(
            [jnp.full((B, cfg.frontend_tokens), -1, jnp.int32), tokens], 1)
    return params, tokens, labels, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    if cfg.is_encdec:
        params = init_params(jax.random.key(0), whisper.whisper_specs(cfg),
                             cfg.dtype)
        frames = jax.random.normal(
            jax.random.key(1), (B, cfg.encoder_ctx, cfg.d_model)) * 0.1
        tokens = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
        loss, m = whisper.whisper_loss(params, cfg, frames, tokens, tokens)
        assert jnp.isfinite(loss)
        enc = whisper.encode(params, cfg, frames)
        assert enc.shape == (B, cfg.encoder_ctx, cfg.d_model)
        assert bool(jnp.isfinite(enc).all())
        return
    params, tokens, labels, extra = _lm_setup(cfg)
    hidden, aux = lm.forward(params, cfg, tokens, extra)
    S_total = S + (cfg.frontend_tokens if extra is not None else 0)
    assert hidden.shape == (B, S_total, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all())
    loss, metrics = lm.lm_loss(params, cfg, tokens, labels, extra)
    assert jnp.isfinite(loss) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    from repro.configs.registry import SHAPES
    from repro.launch.steps import make_train_step
    from repro.train.train_state import init_train_state, make_tx
    tx = make_tx(cfg, total_steps=10)
    from repro.launch.steps import model_specs
    state = init_train_state(jax.random.key(0), cfg, model_specs(cfg), tx)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_ctx, cfg.d_model)) * 0.1
    if cfg.frontend == "vision":
        batch["patches"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                     cfg.dtype)
        batch["labels"] = jnp.concatenate(
            [jnp.full((B, cfg.frontend_tokens), -1, jnp.int32), tokens], 1)
    step = make_train_step(cfg)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state.step) == 1
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_state.params),
                                jax.tree.leaves(state.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["starcoder2_3b", "phi4_mini_3_8b",
                                  "mamba2_1_3b", "jamba_1_5_large_398b",
                                  "qwen3_moe_235b_a22b", "llava_next_34b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params, tokens, labels, extra = _lm_setup(cfg)
    hidden, _ = lm.forward(params, cfg, tokens, extra)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits_tf = hidden[:, -1] @ w
    t_max = (cfg.frontend_tokens if extra is not None else 0) + S + 4
    _, caches, pos = lm.prefill(params, cfg, tokens[:, :-1], extra,
                                t_max=t_max)
    dl, _ = lm.decode_step(params, cfg, caches, tokens[:, -1:], pos)
    np.testing.assert_allclose(np.asarray(logits_tf), np.asarray(dl),
                               atol=3e-3)


def test_full_config_param_counts_match_published():
    published = {   # billions, ±6%
        "llama4_scout_17b_a16e": 109, "qwen3_moe_235b_a22b": 235,
        "starcoder2_7b": 7.2, "phi4_mini_3_8b": 3.8, "nemotron_4_340b": 340,
        "starcoder2_3b": 3.0, "mamba2_1_3b": 1.3,
        "jamba_1_5_large_398b": 398, "whisper_large_v3": 1.54,
        "llava_next_34b": 34.4,
    }
    for arch, target in published.items():
        got = get_config(arch).param_count() / 1e9
        assert abs(got - target) / target < 0.08, (arch, got, target)


def test_active_params_moe():
    assert abs(get_config("llama4_scout_17b_a16e").active_param_count() / 1e9
               - 17) < 1.5
    assert abs(get_config("qwen3_moe_235b_a22b").active_param_count() / 1e9
               - 22) < 1.5
    assert abs(get_config("jamba_1_5_large_398b").active_param_count() / 1e9
               - 94) < 4


# ---------------------------------------------------------------------------
# Component-level
# ---------------------------------------------------------------------------

class TestSSD:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 3), st.integers(2, 6), st.integers(1, 4),
           st.integers(1, 4), st.integers(2, 8))
    def test_chunked_equals_sequential(self, b, s_chunks, h, p2, n):
        S_ = s_chunks * 4
        P = 2 * p2
        ks = jax.random.split(jax.random.key(b * 100 + S_), 4)
        xdt = jax.random.normal(ks[0], (b, S_, h, P)) * 0.5
        a = -jax.nn.softplus(jax.random.normal(ks[1], (b, S_, h)))
        bb = jax.random.normal(ks[2], (b, S_, n)) * 0.5
        cc = jax.random.normal(ks[3], (b, S_, n)) * 0.5
        y_ref, h_ref = ssd.ssd_scan_ref(xdt, a, bb, cc)
        y_chk, h_chk = ssd.ssd_scan_chunked(xdt, a, cc, bb, chunk=4)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_chk),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_chk),
                                   atol=2e-5)

    def test_decode_equals_full(self):
        cfg = get_smoke_config("mamba2_1_3b")
        params = init_params(jax.random.key(1),
                             ssd.ssd_specs(cfg), jnp.float32)
        x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model)) * 0.5
        x1 = jax.random.normal(jax.random.key(3), (2, 1, cfg.d_model)) * 0.5
        out, cache = ssd.ssd_apply(params, cfg, x, return_cache=True)
        full = ssd.ssd_apply(params, cfg, jnp.concatenate([x, x1], 1))
        dec, _ = ssd.ssd_decode(params, cfg, x1, cache)
        np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                                   atol=2e-4)


class TestMoE:
    def test_matches_dense_routing(self):
        cfg = ModelConfig(name="m", n_layers=2, d_model=16, n_heads=2,
                          n_kv_heads=2, d_ff=32, vocab=64,
                          pattern=(("attn", "moe"),), n_experts=4, top_k=2,
                          d_ff_moe=32, capacity_factor=8.0)
        mp = init_params(jax.random.key(4), moe.moe_specs(cfg))
        xm = jax.random.normal(jax.random.key(5), (2, 8, 16)) * 0.5
        y, aux = moe.moe_apply(mp, cfg, xm)
        logits = jnp.einsum("bsd,de->bse", xm, mp["router"])
        probs = jax.nn.softmax(logits, -1)
        gv, gi = jax.lax.top_k(probs, 2)
        g = gv / gv.sum(-1, keepdims=True)
        ref = np.zeros_like(np.asarray(xm))
        for b_ in range(2):
            for s_ in range(8):
                for j in range(2):
                    e = int(gi[b_, s_, j])
                    t = xm[b_, s_]
                    h = t @ mp["w_up"][e]
                    gt = t @ mp["w_gate"][e]
                    o = (jax.nn.silu(gt) * h) @ mp["w_down"][e]
                    ref[b_, s_] += float(g[b_, s_, j]) * np.asarray(o)
        np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4)
        assert float(aux) > 0

    def test_capacity_drops_tokens(self):
        cfg = ModelConfig(name="m", n_layers=2, d_model=8, n_heads=2,
                          n_kv_heads=2, d_ff=16, vocab=64,
                          pattern=(("attn", "moe"),), n_experts=2, top_k=1,
                          d_ff_moe=16, capacity_factor=1.0)
        mp = init_params(jax.random.key(0), moe.moe_specs(cfg))
        x = jnp.ones((1, 16, 8)) * 0.3     # all tokens route identically
        y, aux = moe.moe_apply(mp, cfg, x)
        # over-capacity tokens get zero expert output
        norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
        assert (norms < 1e-6).sum() >= 16 - moe.capacity(cfg, 16)


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position inner products."""
    x = jax.random.normal(jax.random.key(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None, :]
    r = rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               atol=1e-5)
    # relative property: <r(q,i), r(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 16))
    def dot_at(i, j):
        rq = rope(q, jnp.array([[i]]), 1e4)
        rk = rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(rq * rk))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_ce_chunked_equals_full():
    cfg = get_smoke_config("starcoder2_3b")
    import dataclasses
    cfg_full = dataclasses.replace(cfg, ce_chunk=0)
    cfg_chunk = dataclasses.replace(cfg, ce_chunk=8)
    params, tokens, labels, _ = _lm_setup(cfg)
    l1, _ = lm.lm_loss(params, cfg_full, tokens, labels)
    l2, _ = lm.lm_loss(params, cfg_chunk, tokens, labels)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_quantized_kv_decode_close_to_bf16():
    """§Perf H3.1: int8 KV cache decode tracks the exact path (<5% rel)."""
    import dataclasses
    cfg = get_smoke_config("phi4_mini_3_8b")
    cfg_q = dataclasses.replace(cfg, kv_cache_quant=True)
    params, tokens, _, _ = _lm_setup(cfg)
    _, caches, pos = lm.prefill(params, cfg, tokens[:, :-1], t_max=S + 4)
    ref, _ = lm.decode_step(params, cfg, caches, tokens[:, -1:], pos)
    caches_q = lm.init_caches(cfg_q, B, S + 4)
    logits = None
    for t in range(S):
        logits, caches_q = lm.decode_step(params, cfg_q, caches_q,
                                          tokens[:, t:t + 1], jnp.int32(t))
    rel = float(jnp.max(jnp.abs(ref - logits))) / \
        float(jnp.max(jnp.abs(ref)))
    assert rel < 0.05, rel


def test_flash_impl_matches_xla_forward():
    import dataclasses
    cfg = get_smoke_config("starcoder2_7b")
    params, tokens, labels, _ = _lm_setup(cfg)
    hid_x, _ = lm.forward(params, cfg, tokens)
    cfg_f = dataclasses.replace(cfg, attn_impl="flash_interpret",
                                attn_chunk=16)
    hid_f, _ = lm.forward(params, cfg_f, tokens)
    np.testing.assert_allclose(np.asarray(hid_x), np.asarray(hid_f),
                               atol=5e-3)


def test_bf16_grads_close_to_fp32_grads():
    import dataclasses
    cfg = get_smoke_config("phi4_mini_3_8b")     # fp32 smoke dtype
    params, tokens, labels, _ = _lm_setup(cfg)
    g_ref = jax.grad(lambda p: lm.lm_loss(p, cfg, tokens, labels)[0])(params)
    cfg_b = dataclasses.replace(cfg, bf16_grads=True)
    g_b = jax.grad(lambda p: lm.lm_loss(p, cfg_b, tokens, labels)[0])(params)
    # fp32 smoke dtype -> ct_cast is exact identity here
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_grad_accum_matches_single_step():
    """Microbatched step == monolithic step on the same global batch."""
    import dataclasses
    from repro.launch.steps import make_train_step
    from repro.train.train_state import init_train_state, make_tx
    from repro.launch.steps import model_specs
    cfg1 = get_smoke_config("starcoder2_3b")
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg1.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    outs = []
    for cfg in (cfg1, cfg2):
        tx = make_tx(cfg, total_steps=10)
        state = init_train_state(jax.random.key(0), cfg, model_specs(cfg), tx)
        new_state, metrics = make_train_step(cfg)(state, batch)
        outs.append(new_state.params)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_ssd_kernel_impl_matches_xla():
    """`ssd_impl=kernel_interpret` forward == xla chunked path."""
    import dataclasses
    cfg = get_smoke_config("mamba2_1_3b")
    params, tokens, labels, _ = _lm_setup(cfg)
    hid_x, _ = lm.forward(params, cfg, tokens)
    cfg_k = dataclasses.replace(cfg, ssd_impl="kernel_interpret")
    hid_k, _ = lm.forward(params, cfg_k, tokens)
    np.testing.assert_allclose(np.asarray(hid_x), np.asarray(hid_k),
                               atol=2e-4)
