"""Multi-producer ``capture_scan``: R ranks advancing in lockstep inside
one dispatch must be byte-identical to the sequential per-verb reference
(R single puts per emitting step), including ring wrap-around,
last-writer-wins collisions, per-rank t0 staggering, and the committed
watermark."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Client, StoreServer, TableSpec
from repro.core import store as S


def _mk_step_fn():
    def step_fn(carry, rank, t):
        val = jnp.full((2,), t.astype(jnp.float32) * 10.0
                       + rank.astype(jnp.float32))
        return carry + 1.0, S.make_key(rank, t), val
    return step_fn


def _sequential_ref(spec, n_ranks, length, emit_every, t0=0):
    """The per-verb reference: for each emitting step, rank-major puts."""
    st = S.init_table(spec)
    t0s = np.broadcast_to(np.asarray(t0), (n_ranks,))
    for i in range(length):
        if (int(t0s[0]) + i) % emit_every == 0:
            for r in range(n_ranks):
                t = int(t0s[r]) + i
                st = S.put(spec, st, S.make_key(r, t),
                           jnp.full((2,), float(t * 10 + r)))
    return st


def _assert_state_equal(a, b):
    for x, y, name in zip(a, b, a._fields):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), name)


class TestCaptureScanMulti:
    def test_equals_sequential_reference(self):
        spec = TableSpec("t", shape=(2,), capacity=16, engine="ring")
        R, T, E = 3, 7, 2
        got, carry = S.capture_scan_multi(
            spec, S.init_table(spec), _mk_step_fn(), jnp.zeros((R,)), T, R, E)
        _assert_state_equal(got, _sequential_ref(spec, R, T, E))
        assert int(got.count) == S.capture_emit_count_multi(R, T, E)
        np.testing.assert_array_equal(np.asarray(carry), np.full((R,), T))

    def test_ring_wraparound(self):
        """More emitted puts than capacity: the ring must hold exactly the
        last ``capacity`` writes in sequential order."""
        spec = TableSpec("t", shape=(2,), capacity=4, engine="ring")
        R, T = 3, 5                       # 15 puts through a 4-slot ring
        got, _ = S.capture_scan_multi(
            spec, S.init_table(spec), _mk_step_fn(), jnp.zeros((R,)), T, R, 1)
        _assert_state_equal(got, _sequential_ref(spec, R, T, 1))

    def test_collision_ordering_ranks_exceed_capacity(self):
        """R > capacity: one emitting step alone wraps the ring, so the
        intra-batch last-writer-wins path must match R sequential puts."""
        spec = TableSpec("t", shape=(2,), capacity=3, engine="ring")
        R, T = 5, 2
        got, _ = S.capture_scan_multi(
            spec, S.init_table(spec), _mk_step_fn(), jnp.zeros((R,)), T, R, 1)
        _assert_state_equal(got, _sequential_ref(spec, R, T, 1))

    def test_per_rank_t0_staggering(self):
        """Staggered per-rank clocks interleave distinct keys; the gate
        runs on rank 0's clock."""
        spec = TableSpec("t", shape=(2,), capacity=32, engine="ring")
        R, T, E = 2, 6, 2
        t0 = jnp.array([0, 100], jnp.int32)
        got, _ = S.capture_scan_multi(
            spec, S.init_table(spec), _mk_step_fn(), jnp.zeros((R,)), T, R,
            E, t0=t0)
        _assert_state_equal(got, _sequential_ref(spec, R, T, E,
                                                 t0=np.array([0, 100])))
        # rank 1's staggered keys are present under its own clock
        v, found = S.get(spec, got, S.make_key(1, 102))
        assert bool(found) and np.allclose(v, 1021.0)

    def test_chunked_equals_whole(self):
        """Chunked multi-producer capture (carrying t0 forward) ≡ one long
        capture — the chunked driver's invariant."""
        spec = TableSpec("t", shape=(2,), capacity=16, engine="ring")
        R, E = 2, 3
        step_fn = _mk_step_fn()
        whole, _ = S.capture_scan_multi(
            spec, S.init_table(spec), step_fn, jnp.zeros((R,)), 12, R, E)
        chunked = S.init_table(spec)
        carry = jnp.zeros((R,))
        for base in (0, 6):
            chunked, carry = S.capture_scan_multi(
                spec, chunked, step_fn, carry, 6, R, E, t0=base)
        _assert_state_equal(whole, chunked)

    def test_single_rank_degenerates_to_capture_scan(self):
        spec = TableSpec("t", shape=(2,), capacity=8, engine="ring")

        def single(carry, t):
            return carry + 1.0, S.make_key(0, t), \
                jnp.full((2,), t.astype(jnp.float32) * 10.0)

        a, _ = S.capture_scan(spec, S.init_table(spec), single,
                              jnp.zeros(()), 6, 2)
        b, _ = S.capture_scan_multi(spec, S.init_table(spec), _mk_step_fn(),
                                    jnp.zeros((1,)), 6, 1, 2)
        _assert_state_equal(a, b)


class TestClientCaptureScan:
    def test_commit_bumps_watermark_multi(self):
        srv = StoreServer()
        srv.create_table(TableSpec("f", shape=(2,), capacity=32,
                                   engine="ring"))
        client = Client(srv)
        carry = client.capture_scan("f", _mk_step_fn(), jnp.zeros((3,)), 8,
                                    emit_every=2, n_ranks=3)
        want = S.capture_emit_count_multi(3, 8, 2)
        assert srv.watermark("f") == want == srv.watermark_device("f")
        np.testing.assert_array_equal(np.asarray(carry), np.full((3,), 8.0))

    def test_chunked_driver_via_client(self):
        """Two client chunks == one direct capture on the same key stream."""
        spec = TableSpec("f", shape=(2,), capacity=16, engine="ring")
        srv = StoreServer()
        srv.create_table(spec)
        client = Client(srv)
        step_fn = _mk_step_fn()
        carry = jnp.zeros((2,))
        for base in (0, 4):
            carry = client.capture_scan("f", step_fn, carry, 4,
                                        emit_every=2, t0=base, n_ranks=2)
        whole, _ = S.capture_scan_multi(
            spec, S.init_table(spec), step_fn, jnp.zeros((2,)), 8, 2, 2)
        got = srv.checkout("f")
        _assert_state_equal(got, whole)
        assert srv.watermark("f") == int(whole.count)

    def test_single_producer_client_path(self):
        srv = StoreServer()
        srv.create_table(TableSpec("f", shape=(2,), capacity=8,
                                   engine="ring"))
        client = Client(srv)

        def single(carry, t):
            return carry, S.make_key(0, t), \
                jnp.full((2,), t.astype(jnp.float32))

        client.capture_scan("f", single, jnp.zeros(()), 5, emit_every=1)
        assert srv.watermark("f") == 5 == srv.watermark_device("f")
