"""Fig. 6: strong scaling of send/retrieve (co-located, Redis engine).

Paper: total payload fixed at 384MB (≈ a 230³ grid's p+u fields); per-rank
size shrinks with scale; transfer time decreases linearly until the
per-rank message drops under 256KB, where the fixed per-request cost
flattens the curve.  We reproduce both regimes: modeled v5e time =
t_fixed + bytes/HBM_bw with t_fixed calibrated from the measured
small-message host latency, plus measured per-op host cost at several
per-rank sizes.
"""

from __future__ import annotations

import jax

from repro.core import StoreServer, TableSpec
from repro.core.store import make_key

from .common import HW, Row, timeit

TOTAL = 384 * 2**20
RANKS_PER_NODE = 24


def _measure_one(nbytes: int, iters: int):
    elems = max(64, nbytes // 4)
    server = StoreServer()
    server.create_table(TableSpec("t", shape=(elems,), capacity=4,
                                  engine="ring"))
    data = jax.random.normal(jax.random.key(0), (elems,))
    step = [0]

    def send():
        step[0] += 1
        server.put("t", make_key(0, step[0] % 512), data)
        return data

    return timeit(send, iters=iters)


def run(quick: bool = True):
    rows = []
    # calibrate the fixed per-request cost from a tiny message
    t_fixed_host = _measure_one(1024, iters=8)
    t_fixed_v5e = 2e-6            # dispatch-dominated on hardware
    node_counts = (1, 4, 16, 64, 256, 448)
    for n in node_counts:
        ranks = n * RANKS_PER_NODE
        per_rank = TOTAL // ranks
        t_v5e = t_fixed_v5e + 2 * per_rank / HW["hbm_bytes_per_s"]
        derived = (f"ranks={ranks};per_rank_kb={per_rank/1024:.0f};"
                   f"v5e_us={t_v5e*1e6:.1f};"
                   f"regime={'bandwidth' if per_rank >= 256*1024 else 'latency'}")
        if n <= (4 if quick else 64):
            t_host = _measure_one(per_rank, iters=4 if quick else 20)
            rows.append(Row(f"fig6/{n}nodes", t_host * 1e6, derived))
        else:
            rows.append(Row(f"fig6/{n}nodes", 0.0, derived))
    rows.append(Row("fig6/fixed_cost_host", t_fixed_host * 1e6,
                    "calibration=1KB message"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
