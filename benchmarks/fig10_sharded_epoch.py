"""Fig. 10 (beyond-paper): the sharded fused epoch vs mesh size.

The paper's headline result is near-perfect scaling of co-located training
across nodes.  Our structural version: the trainer's whole epoch — store
gather, normalization, mini-batch SGD with DDP gradient all-reduce, and
validation — runs inside ONE ``shard_map`` over a ``data`` mesh axis
(``ml.trainer.make_sharded_fused_epoch``), so dispatches/epoch stays O(1)
at any mesh size.  This benchmark measures epochs/s and store
dispatches/epoch for mesh sizes 1, 2, (4 with ``--full``), with the
single-device fused tier as the mesh=1 baseline, and writes
``BENCH_sharded_epoch.json``.

Each mesh size runs in a fresh subprocess: forcing multiple CPU devices
(``--xla_force_host_platform_device_count``) must happen before the first
jax call, and a fresh process keeps the timings free of each other's
compilation caches.  On a single shared CPU the mesh sizes time-slice one
socket, so epochs/s is NOT expected to scale here — the claim under test
is the O(1) dispatch count and that the sharded tier stays within a small
factor of the baseline; real scaling needs real devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from .common import Row

_CHILD = """
    import json, sys, time
    import jax, jax.numpy as jnp
    from repro.core import StoreServer, TableSpec
    from repro.core import store as S
    from repro.ml import autoencoder as ae, trainer as tr
    from repro.parallel.sharding import data_mesh
    from repro.sim import flatplate as fp
    from repro.train import optimizer as opt

    D, epochs = int(sys.argv[1]), int(sys.argv[2])
    fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    n = fcfg.n_points
    srv = StoreServer()
    srv.create_table(TableSpec("field", shape=(4, n), capacity=16,
                               engine="ring"))
    key = jax.random.key(0)
    for i in range(10):
        srv.put("field", S.make_key(0, i), fp.snapshot(fcfg, key, i))

    aecfg = ae.AEConfig(n_points=n, mode="ref", latent=16, mlp_width=16)
    cfg = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4, lr=1e-3,
                           mesh=(data_mesh(D) if D > 1 else None))
    levels = ae.coords_pyramid(aecfg, fp.grid_coords(fcfg))
    tx = opt.adam(cfg.scaled_lr)
    state = tr.init_state(cfg, jax.random.key(0), tx)
    make = tr.make_sharded_fused_epoch if D > 1 else tr.make_fused_epoch
    epoch_fn = make(cfg, levels, tx, srv.spec("field"))
    mu, sd = jnp.zeros((4,)), jnp.ones((4,))

    # warm the executable on a throwaway table (timed loop = dispatch only)
    dummy = S.init_table(srv.spec("field"))
    jax.block_until_ready(
        epoch_fn(dummy, state, jax.random.key(0), mu, sd)[1])

    rng = jax.random.key(1)
    ops0 = srv.op_count
    t0 = time.perf_counter()
    for e in range(epochs):
        rng, k = jax.random.split(rng)
        with srv.capture("field") as txn:
            state, metrics = epoch_fn(txn.state, state, k, mu, sd)
        jax.block_until_ready(state.params)
    wall = time.perf_counter() - t0
    print(json.dumps({
        "mesh": D,
        "devices": len(jax.devices()),
        "epochs_per_s": epochs / wall,
        "dispatches_per_epoch": (srv.op_count - ops0) / epochs,
        "train_loss": float(metrics[0]),
    }))
"""


def _run_child(mesh_size: int, epochs: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={mesh_size}"
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD),
         str(mesh_size), str(epochs)],
        capture_output=True, text=True, timeout=900, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig10 child (mesh={mesh_size}) failed:\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True):
    mesh_sizes = [1, 2] if quick else [1, 2, 4]
    epochs = 8 if quick else 24
    cells = [_run_child(d, epochs) for d in mesh_sizes]

    base = cells[0]
    result = {
        "bench": "sharded_epoch",
        "epochs": epochs,
        "baseline": "single-device fused tier (mesh=1)",
        "cells": cells,
    }
    if write_json:
        path = Path(json_path) if json_path \
            else Path("BENCH_sharded_epoch.json")
        path.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for c in cells:
        rel = c["epochs_per_s"] / base["epochs_per_s"]
        rows.append(Row(
            f"fig10/mesh{c['mesh']}_epoch", 1e6 / c["epochs_per_s"],
            f"epochs_per_s={c['epochs_per_s']:.2f};"
            f"dispatches_per_epoch={c['dispatches_per_epoch']:.2f};"
            f"vs_baseline={rel:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
