"""Fig. 10 (beyond-paper): the sharded fused epoch vs mesh size.

The paper's headline result is near-perfect scaling of co-located training
across nodes.  Our structural version: the trainer's whole epoch — store
gather, normalization, mini-batch SGD with DDP gradient all-reduce, and
validation — runs inside ONE ``shard_map`` over a ``data`` mesh axis
(``ml.trainer.make_sharded_fused_epoch``), so dispatches/epoch stays O(1)
at any mesh size.  This benchmark declares ONE ``InSituSession``
(flat-plate producer + trainer) and runs it unmodified at mesh sizes 1,
2, (4 with ``--full``) — the session plan resolves the fused tier at
mesh 1 and the sharded-fused tier beyond — measuring epochs/s and store
dispatches/epoch, and writes ``BENCH_sharded_epoch.json``.

Each mesh size runs in a fresh subprocess: forcing multiple CPU devices
(``--xla_force_host_platform_device_count``) must happen before the first
jax call, and a fresh process keeps the timings free of each other's
compilation caches.  On a single shared CPU the mesh sizes time-slice one
socket, so epochs/s is NOT expected to scale here — the claim under test
is the O(1) dispatch count and that the sharded tier stays within a small
factor of the baseline; real scaling needs real devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from .common import Row

_CHILD = """
    import json, sys
    import jax, jax.numpy as jnp
    from repro.core import TableSpec
    from repro.core import store as S
    from repro.insitu import InSituSession, Producer, TrainerConsumer
    from repro.ml import autoencoder as ae, trainer as tr
    from repro.parallel.sharding import data_mesh
    from repro.sim import flatplate as fp

    D, epochs = int(sys.argv[1]), int(sys.argv[2])
    fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    n = fcfg.n_points
    key = jax.random.key(0)

    def step_fn(carry, rank, t):
        return carry, S.make_key(rank, t), fp.snapshot(fcfg, key, t)

    aecfg = ae.AEConfig(n_points=n, mode="ref", latent=16, mlp_width=16)
    cfg = tr.TrainerConfig(ae=aecfg, epochs=epochs, gather=6, batch_size=4,
                           lr=1e-3, mesh=(data_mesh(D) if D > 1 else None))
    # the same declaration at every mesh size; the plan picks the tier
    session = InSituSession(
        tables=[TableSpec("field", shape=(4, n), capacity=16,
                          engine="ring")],
        components=[
            Producer(step_fn, table="field", steps=10, carry=jnp.zeros(()),
                     emit_every=1),
            TrainerConsumer(cfg, fp.grid_coords(fcfg)),
        ])
    plan = session.plan()
    res = session.run(plan=plan, sequential=True, max_wall_s=900)
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    out = res.output("trainer")
    wall = res.run.timers.total("total_training")
    print(json.dumps({
        "mesh": D,
        "devices": len(jax.devices()),
        "tier": plan.component("trainer").tier,
        "epochs_per_s": epochs / wall,
        # measured store dispatches minus the one-off norm bootstrap
        "dispatches_per_epoch":
            (res.op_delta("trainer") - 1) / epochs,
        "train_loss": out.history[-1].train_loss,
    }))
"""


def _run_child(mesh_size: int, epochs: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={mesh_size}"
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD),
         str(mesh_size), str(epochs)],
        capture_output=True, text=True, timeout=900, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig10 child (mesh={mesh_size}) failed:\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True):
    mesh_sizes = [1, 2] if quick else [1, 2, 4]
    epochs = 8 if quick else 24
    cells = [_run_child(d, epochs) for d in mesh_sizes]

    base = cells[0]
    result = {
        "bench": "sharded_epoch",
        "epochs": epochs,
        "baseline": "single-device fused tier (mesh=1)",
        "cells": cells,
    }
    if write_json:
        path = Path(json_path) if json_path \
            else Path("BENCH_sharded_epoch.json")
        path.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for c in cells:
        rel = c["epochs_per_s"] / base["epochs_per_s"]
        rows.append(Row(
            f"fig10/mesh{c['mesh']}_epoch", 1e6 / c["epochs_per_s"],
            f"epochs_per_s={c['epochs_per_s']:.2f};"
            f"dispatches_per_epoch={c['dispatches_per_epoch']:.2f};"
            f"vs_baseline={rel:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
