"""Fig. 10 (beyond-paper): the sharded fused epoch vs mesh size, and the
replicated vs slab-sharded data-plane entry.

The paper's headline result is near-perfect scaling of co-located training
across nodes.  Our structural version: the trainer's whole epoch — store
gather, normalization, mini-batch SGD with DDP gradient all-reduce, and
validation — runs inside ONE ``shard_map`` over a ``data`` mesh axis
(``ml.trainer.make_sharded_fused_epoch``), so dispatches/epoch stays O(1)
at any mesh size.  This benchmark declares ONE ``InSituSession``
(flat-plate producer + trainer) and runs it unmodified across mesh sizes
and both data-plane entries — the session plan resolves the fused tier at
mesh 1, sharded-fused beyond, and ``slab_sharded`` when the config asks
for the pre-partitioned table — measuring epochs/s and store
dispatches/epoch, and writes ``BENCH_sharded_epoch.json``.

The **entry comparison** (mesh 2, replicated vs slab-sharded) is the
data-plane claim: with the slab-sharded entry the compiled epoch contains
ZERO table all-gathers (measured via ``plan(hlo=True)``), per-device slab
bytes drop by the mesh factor, and throughput stays within noise of the
replicated entry.  ``tools/check_bench.py`` gates all three.

Each cell runs in a fresh subprocess: forcing multiple CPU devices
(``--xla_force_host_platform_device_count``) must happen before the first
jax call, and a fresh process keeps the timings free of each other's
compilation caches.  On a single shared CPU the mesh sizes time-slice one
socket, so epochs/s is NOT expected to scale here — the claims under test
are structural; real scaling needs real devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from .common import Row

_CHILD = """
    import json, sys
    import jax, jax.numpy as jnp
    from repro.core import TableSpec
    from repro.core import store as S
    from repro.insitu import InSituSession, Producer, TrainerConsumer
    from repro.ml import autoencoder as ae, trainer as tr
    from repro.parallel.sharding import data_mesh
    from repro.sim import flatplate as fp

    D, epochs, slab, hlo = (int(sys.argv[1]), int(sys.argv[2]),
                            bool(int(sys.argv[3])), bool(int(sys.argv[4])))
    fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    n = fcfg.n_points
    key = jax.random.key(0)

    def step_fn(carry, rank, t):
        return carry, S.make_key(rank, t), fp.snapshot(fcfg, key, t)

    aecfg = ae.AEConfig(n_points=n, mode="ref", latent=16, mlp_width=16)
    cfg = tr.TrainerConfig(ae=aecfg, epochs=epochs, gather=6, batch_size=4,
                           lr=1e-3, mesh=(data_mesh(D) if D > 1 else None),
                           slab_sharded=slab)
    # the same declaration at every mesh size; the plan picks the tier
    spec = TableSpec("field", shape=(4, n), capacity=16, engine="ring")
    session = InSituSession(
        tables=[spec],
        components=[
            Producer(step_fn, table="field", steps=10, carry=jnp.zeros(()),
                     emit_every=1),
            TrainerConsumer(cfg, fp.grid_coords(fcfg)),
        ])
    # entry-structure ground truth from compiled HLO (the data-plane
    # claim).  Compiled only for the cells the check_bench gate reads
    # (the driver sets hlo=1 for the entry-comparison pair) — the compile
    # otherwise doubles the cell's wall time for numbers nothing consumes.
    coll = {}
    if hlo:
        hplan = session.plan(hlo=True)
        for entry in hplan.components:
            entry.check_collectives()
        coll = dict(hplan.component("trainer").collectives)
    plan = session.plan()
    res = session.run(plan=plan, sequential=True, max_wall_s=900)
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    out = res.output("trainer")
    wall = res.run.timers.total("total_training")
    # per-device slab memory: MEASURED from the live table's placement
    # (the data-plane claim is about where bytes actually sit, so a
    # placement regression must show up here, not be derived away)
    live_slab = res.server.checkout("field").slab
    slab_bytes_dev = max(s.data.nbytes for s in live_slab.addressable_shards)
    print(json.dumps({
        "mesh": D,
        "devices": len(jax.devices()),
        "tier": plan.component("trainer").tier,
        "entry": "slab_sharded" if slab else "replicated",
        "epochs_per_s": epochs / wall,
        # measured store dispatches minus the one-off norm bootstrap
        "dispatches_per_epoch":
            (res.op_delta("trainer") - 1) / epochs,
        "slab_bytes_per_device": slab_bytes_dev,
        "all_gather": coll.get("all-gather", 0),
        "all_reduce": coll.get("all-reduce", 0),
        "train_loss": out.history[-1].train_loss,
    }))
"""


def _run_child(mesh_size: int, epochs: int, slab: bool = False,
               hlo: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={mesh_size}"
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD),
         str(mesh_size), str(epochs), str(int(slab)), str(int(hlo))],
        capture_output=True, text=True, timeout=900, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig10 child (mesh={mesh_size}, slab={slab}) failed:\n"
            f"{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _entry_comparison(cells: list[dict]) -> dict | None:
    """Replicated vs slab-sharded entry at the same (largest shared) mesh
    size — the gate ``tools/check_bench.py`` reads."""
    by_entry: dict[str, dict] = {}
    for c in cells:
        if c["mesh"] > 1:
            prev = by_entry.get(c["entry"])
            if prev is None or c["mesh"] > prev["mesh"]:
                by_entry[c["entry"]] = c
    if set(by_entry) != {"replicated", "slab_sharded"} or \
            by_entry["replicated"]["mesh"] != by_entry["slab_sharded"]["mesh"]:
        return None
    rep, slab = by_entry["replicated"], by_entry["slab_sharded"]
    return {
        "mesh": rep["mesh"],
        "epochs_per_s_ratio": slab["epochs_per_s"] / rep["epochs_per_s"],
        "slab_entry_all_gather": slab["all_gather"],
        "slab_entry_all_reduce": slab["all_reduce"],
        "entry_bytes_ratio":
            rep["slab_bytes_per_device"] / slab["slab_bytes_per_device"],
        "dispatches_per_epoch": {
            "replicated": rep["dispatches_per_epoch"],
            "slab_sharded": slab["dispatches_per_epoch"],
        },
    }


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True, smoke: bool = False):
    if smoke:
        grid = [(2, False), (2, True)]
        epochs = 4
    elif quick:
        grid = [(1, False), (2, False), (2, True)]
        epochs = 8
    else:
        grid = [(1, False), (2, False), (2, True), (4, False), (4, True)]
        epochs = 24
    # HLO collective counts are compiled only for the pair the
    # entry-comparison gate reads: the largest mesh size with both entries.
    cmp_mesh = max(d for d, _ in grid if d > 1)
    cells = [_run_child(d, epochs, slab, hlo=(d == cmp_mesh))
             for d, slab in grid]

    base = cells[0]
    result = {
        "bench": "sharded_epoch",
        "epochs": epochs,
        "baseline": f"{base['entry']} entry, mesh={base['mesh']}",
        "cells": cells,
        "entry_comparison": _entry_comparison(cells),
    }
    if write_json:
        path = Path(json_path) if json_path \
            else Path("BENCH_sharded_epoch.json")
        path.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for c in cells:
        rel = c["epochs_per_s"] / base["epochs_per_s"]
        rows.append(Row(
            f"fig10/mesh{c['mesh']}_{c['entry']}_epoch",
            1e6 / c["epochs_per_s"],
            f"epochs_per_s={c['epochs_per_s']:.2f};"
            f"dispatches_per_epoch={c['dispatches_per_epoch']:.2f};"
            f"all_gather={c['all_gather']};"
            f"vs_baseline={rel:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
