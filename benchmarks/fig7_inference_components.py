"""Fig. 7: in-situ inference component costs vs the in-line baseline.

Paper: ResNet50 through the framework = send + model-eval + retrieve; the
tightly-coupled LibTorch path is 2× (b=1) to 4.6× (b=4,16) faster on
evaluation, but costs ~70 lines of Fortran/C++ bridge vs <10 lines here.

We measure all three components separately (paper protocol), the in-line
jit call (LibTorch analogue), and our beyond-paper *fused* registry path
(single dispatch through the store's model registry — producer stays
model-agnostic AND matches in-line cost).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Client, StoreServer, TableSpec
from repro.ml.resnet import apply_resnet50, init_resnet50

from .common import Row, timeit


def run(quick: bool = True):
    batches = (1, 4) if quick else (1, 4, 16)
    iters = 3 if quick else 10
    rows = []
    params = init_resnet50(jax.random.key(0))
    inline = jax.jit(apply_resnet50)
    server = StoreServer()
    client = Client(server)
    client.set_model("resnet50", apply_resnet50, params)
    for b in batches:
        x = jax.random.normal(jax.random.key(1), (b, 3, 224, 224))
        jax.block_until_ready(x)
        out_shape = (b, 1000)
        for t in (f"in_{b}", f"out_{b}"):
            pass
        server.create_table(TableSpec(f"in_{b}", shape=x.shape, capacity=2,
                                      engine="hash"))
        server.create_table(TableSpec(f"out_{b}", shape=out_shape,
                                      capacity=2, engine="hash"))

        def send():
            client.put_tensor("x", x, table=f"in_{b}")
            return x

        def run_model():
            client.run_model("resnet50", inputs=["x"], outputs=["y"],
                             table=f"in_{b}", out_table=f"out_{b}")
            return server.get(f"out_{b}", 0)[0]

        def retrieve():
            y, _ = client.get_tensor("y", table=f"out_{b}")
            return y

        t_send = timeit(send, iters=iters)
        t_eval = timeit(run_model, iters=iters)
        t_retr = timeit(retrieve, iters=iters)
        t_inline = timeit(lambda: inline(params, x), iters=iters)
        t_fused = timeit(lambda: client.infer("resnet50", x), iters=iters)
        total = t_send + t_eval + t_retr
        rows += [
            Row(f"fig7/b{b}/send", t_send * 1e6, ""),
            Row(f"fig7/b{b}/model_eval", t_eval * 1e6, ""),
            Row(f"fig7/b{b}/retrieve", t_retr * 1e6, ""),
            Row(f"fig7/b{b}/total_3step", total * 1e6,
                f"send_frac={t_send/total:.2f}"),
            Row(f"fig7/b{b}/inline_baseline", t_inline * 1e6,
                f"speedup_vs_3step={total/t_inline:.2f}x"),
            Row(f"fig7/b{b}/fused_registry", t_fused * 1e6,
                f"speedup_vs_3step={total/t_fused:.2f}x"),
        ]
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
