"""Aggregate the dry-run cell JSONs into the §Roofline table.

Reads ``experiments/dryrun/*.json`` and emits one row per (arch × shape ×
mesh): the three roofline terms, the dominant bound, MODEL_FLOPS ratio and
per-device memory estimate — plus a markdown table to
``experiments/roofline.md`` for EXPERIMENTS.md inclusion.
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import Row

DRYRUN_DIR = Path("experiments/dryrun")


def load_cells(dryrun_dir: Path = DRYRUN_DIR, include_variants: bool = False):
    cells = []
    for p in sorted(dryrun_dir.glob("*.json")):
        if not include_variants and len(p.stem.split("__")) > 3:
            continue      # perf-variant cells live in EXPERIMENTS §Perf
        cells.append(json.loads(p.read_text()))
    return cells


def markdown(cells) -> str:
    lines = [
        "| arch | shape | mesh | chips | t_compute | t_memory | t_coll | "
        "bound | useful/machine | roofline frac | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "ok":
            if c.get("status") == "skipped":
                lines.append(
                    f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — "
                    f"| — | skipped | — | — | — |")
            continue
        rt = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['chips']} "
            f"| {rt['t_compute']*1e3:.2f}ms | {rt['t_memory']*1e3:.2f}ms "
            f"| {rt['t_collective']*1e3:.2f}ms | **{rt['bound']}** "
            f"| {rt['useful_ratio']:.2f} | {rt['roofline_fraction']:.3f} "
            f"| {c['bytes_per_device_est']/2**30:.2f} |")
    return "\n".join(lines)


def run(quick: bool = True):
    cells = load_cells()
    rows = []
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    err = [c for c in cells if c.get("status") == "error"]
    rows.append(Row("roofline/cells_ok", 0.0,
                    f"ok={len(ok)};skipped={len(skipped)};errors={len(err)}"))
    for c in ok:
        rt = c["roofline"]
        step_ms = max(rt["t_compute"], rt["t_memory"],
                      rt["t_collective"]) * 1e3
        rows.append(Row(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            step_ms * 1e3,
            f"bound={rt['bound']};frac={rt['roofline_fraction']:.3f};"
            f"useful={rt['useful_ratio']:.2f};chips={c['chips']}"))
    if ok:
        md = markdown(cells)
        out = Path("experiments/roofline.md")
        out.write_text(md + "\n")
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
