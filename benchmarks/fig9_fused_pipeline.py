"""Fig. 9 (beyond-paper): per-verb vs fused in-situ pipeline.

The paper's loose coupling pays one host dispatch per store verb.  The
fused pipeline (``store.capture_scan`` on the producer side,
``store.sample_and_step`` on the consumer side) folds k producer steps +
ring puts — or a gather + the training microstep — into ONE dispatch.
This benchmark measures both tiers doing *identical math* on identical
tables and reports

  * wall-clock steps/s (producer) and epochs/s (consumer), and
  * store dispatches per step (from ``StoreServer.op_count`` — the
    structural O(k) vs O(1) claim, counted, not asserted),

and writes the machine-readable result to ``BENCH_fused_pipeline.json``
for the perf trajectory.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import StoreServer, TableSpec
from repro.core import store as S

from .common import Row

SHAPE = (4, 256)
CAPACITY = 128
GATHER = 8
BATCH = 4


def _make_server() -> StoreServer:
    srv = StoreServer()
    srv.create_table(TableSpec("field", shape=SHAPE, capacity=CAPACITY,
                               engine="ring"))
    return srv


def _snap(t):
    """The stand-in solver step: cheap, so dispatch overhead dominates —
    exactly the regime the fused pipeline targets."""
    t = jnp.asarray(t, jnp.float32)
    return jnp.full(SHAPE, 1.0, jnp.float32) * (1.0 + t)


_snap_jit = jax.jit(_snap)


def _step_fn(carry, t):
    return carry, S.make_key(0, t), _snap(t)


def _producer_per_verb(srv: StoreServer, steps: int, t0: int) -> None:
    for t in range(t0, t0 + steps):
        srv.put("field", S.make_key(0, t), _snap_jit(t))
    jax.block_until_ready(srv.checkout("field").count)


def _producer_fused(srv: StoreServer, spec, steps: int, t0: int) -> None:
    with srv.capture("field") as txn:
        txn.state, _ = S.capture_scan(spec, txn.state, _step_fn,
                                      jnp.zeros(()), steps, 1, t0=t0)
        txn.puts = steps
    jax.block_until_ready(srv.checkout("field").count)


def _micro(w, batch):
    g = jax.grad(
        lambda w: jnp.mean((batch.reshape(batch.shape[0], -1) @ w) ** 2))(w)
    return w - 1e-3 * g


_micro_jit = jax.jit(_micro)


def _epoch_fn(w, values):
    batches = values.reshape(GATHER // BATCH, BATCH, *SHAPE)

    def body(w, b):
        return _micro(w, b), jnp.zeros(())

    w, _ = jax.lax.scan(body, w, batches)
    return w, jnp.zeros(())


def _consumer_per_verb(srv: StoreServer, w, rng):
    vals, _, _ = srv.sample("field", rng, GATHER)
    for i in range(GATHER // BATCH):
        w = _micro_jit(w, vals[i * BATCH:(i + 1) * BATCH])
    jax.block_until_ready(w)
    return w


def _consumer_fused(srv: StoreServer, spec, w, rng):
    with srv.capture("field") as txn:
        w, _, _ = S.sample_and_step(spec, txn.state, rng, GATHER,
                                    _epoch_fn, w)
    jax.block_until_ready(w)
    return w


def _bench(fn, reps: int):
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True):
    steps = 64 if quick else 256
    reps = 5 if quick else 11
    epochs = 8 if quick else 32

    # ---- producer: k per-verb puts vs one capture_scan -------------------
    srv_v = _make_server()
    srv_f = _make_server()
    spec = srv_f.spec("field")
    _producer_per_verb(srv_v, steps, 0)                       # warm/compile
    _producer_fused(srv_f, spec, steps, 0)

    # both tiers advance through the same t-stream so the tables stay
    # identical for the consumer phase
    clock_v = {"t": steps}
    clock_f = {"t": steps}

    def verb_run():
        _producer_per_verb(srv_v, steps, clock_v["t"])
        clock_v["t"] += steps

    def fused_run():
        _producer_fused(srv_f, spec, steps, clock_f["t"])
        clock_f["t"] += steps

    ops0 = srv_v.op_count
    t_verb = _bench(verb_run, reps)
    d_verb = (srv_v.op_count - ops0) / (reps * steps)

    ops0 = srv_f.op_count
    t_fused = _bench(fused_run, reps)
    d_fused = (srv_f.op_count - ops0) / (reps * steps)

    # ---- consumer: per-verb epoch vs fused sample_and_step ---------------
    w0 = jnp.zeros((SHAPE[0] * SHAPE[1], 8), jnp.float32)
    rng = jax.random.key(0)
    _consumer_per_verb(srv_v, w0, rng)                        # warm/compile
    _consumer_fused(srv_f, spec, w0, rng)

    ops0 = srv_v.op_count
    t0 = time.perf_counter()
    w = w0
    for e in range(epochs):
        w = _consumer_per_verb(srv_v, w, jax.random.fold_in(rng, e))
    t_epoch_verb = (time.perf_counter() - t0) / epochs
    d_epoch_verb = (srv_v.op_count - ops0) / epochs

    ops0 = srv_f.op_count
    t0 = time.perf_counter()
    w = w0
    for e in range(epochs):
        w = _consumer_fused(srv_f, spec, w, jax.random.fold_in(rng, e))
    t_epoch_fused = (time.perf_counter() - t0) / epochs
    d_epoch_fused = (srv_f.op_count - ops0) / epochs

    result = {
        "bench": "fused_pipeline",
        "steps_per_chunk": steps,
        "producer": {
            "per_verb": {"steps_per_s": steps / t_verb,
                         "dispatches_per_step": d_verb},
            "fused": {"steps_per_s": steps / t_fused,
                      "dispatches_per_step": d_fused},
            "speedup": t_verb / t_fused,
        },
        "consumer": {
            # store_dispatches: measured via op_count.  host_dispatches:
            # store + SGD microsteps (the per-verb loop dispatches each
            # mini-batch separately; the fused epoch is one dispatch).
            "per_verb": {"epochs_per_s": 1.0 / t_epoch_verb,
                         "store_dispatches_per_epoch": d_epoch_verb,
                         "host_dispatches_per_epoch":
                             d_epoch_verb + GATHER // BATCH},
            "fused": {"epochs_per_s": 1.0 / t_epoch_fused,
                      "store_dispatches_per_epoch": d_epoch_fused,
                      "host_dispatches_per_epoch": d_epoch_fused},
            "speedup": t_epoch_verb / t_epoch_fused,
        },
    }
    if write_json:
        path = Path(json_path) if json_path \
            else Path("BENCH_fused_pipeline.json")
        path.write_text(json.dumps(result, indent=2) + "\n")

    prod, cons = result["producer"], result["consumer"]
    return [
        Row("fig9/producer_per_verb", t_verb / steps * 1e6,
            f"steps_per_s={prod['per_verb']['steps_per_s']:.0f};"
            f"dispatches_per_step={d_verb:.3f}"),
        Row("fig9/producer_fused", t_fused / steps * 1e6,
            f"steps_per_s={prod['fused']['steps_per_s']:.0f};"
            f"dispatches_per_step={d_fused:.4f}"),
        Row("fig9/producer_speedup", prod["speedup"] * 1e6,
            f"x={prod['speedup']:.2f}"),
        Row("fig9/consumer_per_verb_epoch", t_epoch_verb * 1e6,
            f"host_dispatches_per_epoch={d_epoch_verb + GATHER // BATCH:.2f}"),
        Row("fig9/consumer_fused_epoch", t_epoch_fused * 1e6,
            f"host_dispatches_per_epoch={d_epoch_fused:.2f}"),
        Row("fig9/consumer_speedup", cons["speedup"] * 1e6,
            f"x={cons['speedup']:.2f}"),
    ]


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
