"""Fig. 9 (beyond-paper): per-verb vs fused in-situ pipeline.

The paper's loose coupling pays one host dispatch per store verb; the
fused tiers fold whole chunks of producer steps — or whole training
epochs — into single dispatches.  This benchmark declares the SAME
``InSituSession`` twice (a flat-plate producer + a QuadConv-autoencoder
trainer) and forces it through the per-verb and fused points of the tier
grid, reporting

  * producer steps/s and consumer epochs/s per tier, and
  * store dispatches per step / per epoch, measured from the session's
    per-component op deltas (the structural O(k)-vs-O(1) claim, counted
    not asserted) and cross-checked against ``plan.explain()``,

and writes the machine-readable result to ``BENCH_fused_pipeline.json``
for the perf trajectory.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax.numpy as jnp

from repro.core import TableSpec
from repro.core import store as S
from repro.insitu import InSituSession, Producer, TrainerConsumer
from repro.ml import autoencoder as ae
from repro.ml import trainer as tr
from repro.sim import flatplate as fp

from .common import Row

FCFG = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
CAPACITY = 24
GATHER = 6
BATCH = 4


def _step_fn(carry, rank, t):
    """Cheap stand-in solver step, so dispatch overhead dominates —
    exactly the regime the fused pipeline targets."""
    snap = jnp.full((4, FCFG.n_points), 1.0, jnp.float32) \
        * (1.0 + jnp.asarray(t, jnp.float32))
    return carry, S.make_key(rank, t), snap


def _session(producer_tier: str, trainer_tier: str, steps: int,
             epochs: int) -> InSituSession:
    cfg = tr.TrainerConfig(
        ae=ae.AEConfig(n_points=FCFG.n_points, mode="ref", latent=16,
                       mlp_width=16),
        epochs=epochs, gather=GATHER, batch_size=BATCH, lr=1e-3,
        fused=(trainer_tier != "per_verb"))
    return InSituSession(
        tables=[TableSpec("field", shape=(4, FCFG.n_points),
                          capacity=CAPACITY, engine="ring")],
        components=[
            Producer(_step_fn, table="field", steps=steps,
                     carry=jnp.zeros(()), emit_every=1, tier=producer_tier),
            TrainerConsumer(cfg, fp.grid_coords(FCFG), tier=trainer_tier),
        ])


def _measure(producer_tier: str, trainer_tier: str, steps: int,
             epochs: int) -> dict:
    session = _session(producer_tier, trainer_tier, steps, epochs)
    plan = session.plan()
    res = session.run(plan=plan, sequential=True, max_wall_s=1200)
    assert res.ok, {k: v.error for k, v in res.run.components.items()
                    if v.error}
    t = res.run.timers
    # producer cost = solver + send enqueue/commit (compile is bucketed
    # separately); consumer cost = the trainer's epoch-loop wall.
    prod_s = t.total("equation_solution") + t.total("send")
    train_s = t.total("total_training")
    d_prod = res.op_delta("producer")
    d_train = res.op_delta("trainer")
    explain = plan.explain()["components"]
    assert d_prod == plan.component("producer").store_dispatches
    assert d_train == plan.component("trainer").store_dispatches
    n_batches = -(-(GATHER - 1) // BATCH)
    host_per_epoch = 1.0 if trainer_tier != "per_verb" \
        else 1 + 1 + n_batches + 1   # sample + prep + micros + validate
    return {
        "steps_per_s": steps / max(prod_s, 1e-9),
        "epochs_per_s": epochs / max(train_s, 1e-9),
        "dispatches_per_step": explain["producer"]["dispatches_per_step"],
        # measured store dispatches, minus the one-off norm bootstrap
        "store_dispatches_per_epoch": (d_train - 1) / epochs,
        "host_dispatches_per_epoch": host_per_epoch,
    }


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True, smoke: bool = False):
    if smoke:
        # producer steps match the quick profile: the fused/per-verb
        # speedup shrinks with the step count (dispatch amortization), so
        # the smoke gate's ratio is only comparable to the committed
        # quick-profile baseline at the same workload.  The consumer side
        # is gated structurally, so its epochs stay minimal.
        steps, epochs = 64, 3
    elif quick:
        steps, epochs = 64, 8
    else:
        steps, epochs = 256, 24

    verb = _measure("per_verb", "per_verb", steps, epochs)
    fused = _measure("capture_scan", "fused", steps, epochs)

    result = {
        "bench": "fused_pipeline",
        "api": "insitu_session",
        "steps": steps,
        "epochs": epochs,
        "producer": {
            "per_verb": {"steps_per_s": verb["steps_per_s"],
                         "dispatches_per_step":
                             verb["dispatches_per_step"]},
            "fused": {"steps_per_s": fused["steps_per_s"],
                      "dispatches_per_step":
                          fused["dispatches_per_step"]},
            "speedup": fused["steps_per_s"] / verb["steps_per_s"],
        },
        "consumer": {
            "per_verb": {"epochs_per_s": verb["epochs_per_s"],
                         "store_dispatches_per_epoch":
                             verb["store_dispatches_per_epoch"],
                         "host_dispatches_per_epoch":
                             verb["host_dispatches_per_epoch"]},
            "fused": {"epochs_per_s": fused["epochs_per_s"],
                      "store_dispatches_per_epoch":
                          fused["store_dispatches_per_epoch"],
                      "host_dispatches_per_epoch":
                          fused["host_dispatches_per_epoch"]},
            "speedup": fused["epochs_per_s"] / verb["epochs_per_s"],
        },
    }
    if write_json:
        path = Path(json_path) if json_path \
            else Path("BENCH_fused_pipeline.json")
        path.write_text(json.dumps(result, indent=2) + "\n")

    prod, cons = result["producer"], result["consumer"]
    return [
        Row("fig9/producer_per_verb",
            1e6 / prod["per_verb"]["steps_per_s"],
            f"steps_per_s={prod['per_verb']['steps_per_s']:.0f};"
            f"dispatches_per_step="
            f"{prod['per_verb']['dispatches_per_step']:.3f}"),
        Row("fig9/producer_fused",
            1e6 / prod["fused"]["steps_per_s"],
            f"steps_per_s={prod['fused']['steps_per_s']:.0f};"
            f"dispatches_per_step="
            f"{prod['fused']['dispatches_per_step']:.4f}"),
        Row("fig9/producer_speedup", prod["speedup"] * 1e6,
            f"x={prod['speedup']:.2f}"),
        Row("fig9/consumer_per_verb_epoch",
            1e6 / cons["per_verb"]["epochs_per_s"],
            f"host_dispatches_per_epoch="
            f"{cons['per_verb']['host_dispatches_per_epoch']:.2f}"),
        Row("fig9/consumer_fused_epoch",
            1e6 / cons["fused"]["epochs_per_s"],
            f"host_dispatches_per_epoch="
            f"{cons['fused']['host_dispatches_per_epoch']:.2f}"),
        Row("fig9/consumer_speedup", cons["speedup"] * 1e6,
            f"x={cons['speedup']:.2f}"),
    ]


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
