"""Benchmark entry point: ``python -m benchmarks.run [--full]``.

One function per paper table/figure; prints ``name,us_per_call,derived``
CSV.  Default is the quick profile (CI-friendly); ``--full`` runs the
paper-fidelity iteration counts.
"""

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (fig3,...,table12,roofline)")
    args = ap.parse_args()
    quick = not args.full

    from . import (fig3_store_budget, fig4_size_sweep, fig5_weak_scaling,
                   fig6_strong_scaling, fig7_inference_components,
                   fig8_inference_scaling, roofline_table,
                   table12_insitu_overhead)
    benches = {
        "fig3": fig3_store_budget.run,
        "fig4": fig4_size_sweep.run,
        "fig5": fig5_weak_scaling.run,
        "fig6": fig6_strong_scaling.run,
        "fig7": fig7_inference_components.run,
        "fig8": fig8_inference_scaling.run,
        "table12": table12_insitu_overhead.run,
        "roofline": roofline_table.run,
    }
    if args.only:
        names = args.only.split(",")
        benches = {k: v for k, v in benches.items() if k in names}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            for row in fn(quick=quick):
                print(row.csv(), flush=True)
            print(f"_meta/{name}/wall_s,{(time.perf_counter()-t0)*1e6:.0f},",
                  flush=True)
        except Exception:
            failures += 1
            print(f"_meta/{name}/ERROR,0,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
