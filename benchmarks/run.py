"""Benchmark entry point: ``python -m benchmarks.run [--full] [--json]``.

One function per paper table/figure; prints ``name,us_per_call,derived``
CSV.  Default is the quick profile (CI-friendly); ``--full`` runs the
paper-fidelity iteration counts; ``--smoke`` runs only the session-API
pipeline bench (fig9) at minimal counts — the CI regression gate pairs it
with ``tools/check_bench.py``.  ``--json`` additionally writes one
``BENCH_<name>.json`` per bench (rows + wall time) so the perf trajectory
is machine-readable.
"""

import argparse
import json
import sys
import time
import traceback
from dataclasses import asdict
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal session-API run (fig9, the fig10 "
                         "replicated-vs-slab-sharded entry cells, the "
                         "fig5 clustered fan-in cells, the serving "
                         "continuous-batching cells, and the turbulence "
                         "sharded-producer cells) for the CI bench gate")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<name>.json per bench")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_*.json files")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (fig3,...,table12,roofline)")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    quick = not args.full

    from . import (chaos_overhead, fig3_store_budget, fig4_size_sweep,
                   fig5_weak_scaling, fig6_strong_scaling,
                   fig7_inference_components, fig8_inference_scaling,
                   fig9_fused_pipeline, fig10_sharded_epoch, fig_serving,
                   fig_turbulence, roofline_table,
                   table12_insitu_overhead)
    benches = {
        "fig3": fig3_store_budget.run,
        "fig4": fig4_size_sweep.run,
        "fig5": fig5_weak_scaling.run,
        "fig6": fig6_strong_scaling.run,
        "fig7": fig7_inference_components.run,
        "fig8": fig8_inference_scaling.run,
        "fig9": fig9_fused_pipeline.run,
        "fig10": fig10_sharded_epoch.run,
        "table12": table12_insitu_overhead.run,
        "roofline": roofline_table.run,
        "chaos": chaos_overhead.run,
        "serving": fig_serving.run,
        "turbulence": fig_turbulence.run,
    }
    if args.smoke:
        benches = {k: v for k, v in benches.items()
                   if k in ("fig5", "fig9", "fig10", "serving",
                            "turbulence")}
    if args.only:
        names = args.only.split(",")
        unknown = [n for n in names if n not in benches]
        if unknown:
            ap.error(f"unknown bench name(s): {', '.join(unknown)} "
                     f"(choose from {', '.join(benches)})")
        benches = {k: v for k, v in benches.items() if k in names}
    if args.json:
        Path(args.json_dir).mkdir(parents=True, exist_ok=True)
    if "fig9" in benches:
        # fig9/fig10 structured result files are opt-in here like every
        # other BENCH_*.json, and land in --json-dir, not the invoker's
        # CWD.  (Standalone `python -m benchmarks.fig9_fused_pipeline` /
        # `... fig10_sharded_epoch` still writes them by default.)
        benches["fig9"] = (lambda quick: fig9_fused_pipeline.run(
            quick=quick, smoke=args.smoke, write_json=args.json,
            json_path=str(Path(args.json_dir)
                          / "BENCH_fused_pipeline.json")))
    if "fig10" in benches:
        benches["fig10"] = (lambda quick: fig10_sharded_epoch.run(
            quick=quick, smoke=args.smoke, write_json=args.json,
            json_path=str(Path(args.json_dir)
                          / "BENCH_sharded_epoch.json")))
    if "fig5" in benches:
        benches["fig5"] = (lambda quick: fig5_weak_scaling.run(
            quick=quick, smoke=args.smoke, write_json=args.json,
            json_path=str(Path(args.json_dir)
                          / "BENCH_weak_scaling.json")))
    if "serving" in benches:
        benches["serving"] = (lambda quick: fig_serving.run(
            quick=quick, smoke=args.smoke, write_json=args.json,
            json_path=str(Path(args.json_dir) / "BENCH_serving.json")))
    if "turbulence" in benches:
        benches["turbulence"] = (lambda quick: fig_turbulence.run(
            quick=quick, smoke=args.smoke, write_json=args.json,
            json_path=str(Path(args.json_dir)
                          / "BENCH_turbulence.json")))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            rows = []
            for row in fn(quick=quick):
                rows.append(row)
                print(row.csv(), flush=True)
            wall_s = time.perf_counter() - t0
            print(f"_meta/{name}/wall_s,{wall_s*1e6:.0f},", flush=True)
            if args.json:
                # "serving"/"turbulence" write their structured gate
                # files under BENCH_<name>.json themselves; keep the
                # generic rows dump from clobbering them.
                stem = f"{name}_rows" if name in ("serving",
                                                  "turbulence") else name
                out = Path(args.json_dir) / f"BENCH_{stem}.json"
                out.write_text(json.dumps(
                    {"bench": name, "quick": quick, "wall_s": wall_s,
                     "rows": [asdict(r) for r in rows]}, indent=2) + "\n")
        except Exception:
            failures += 1
            print(f"_meta/{name}/ERROR,0,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
