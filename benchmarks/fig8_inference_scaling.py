"""Fig. 8: weak + strong scaling of in-situ inference (co-located).

Paper: weak scaling (fixed per-rank batch) is perfectly flat; strong
scaling of model evaluation degrades at small per-rank batch but the total
(transfer + eval) stays linear because the transfer shrinks 1/N.

Methodology here: the co-located deployment is embarrassingly parallel
(zero collective bytes — fig5's structural proof covers inference traffic
too), so per-device cost is the single-device cost at the per-device batch.
We measure eval+transfer vs batch on the host and project the curves.
"""

from __future__ import annotations

import jax

from repro.core import Client, StoreServer, TableSpec
from repro.ml.resnet import apply_resnet50, init_resnet50

from .common import Row, timeit


def _eval_time_vs_batch(batches, iters):
    params = init_resnet50(jax.random.key(0))
    fn = jax.jit(apply_resnet50)
    out = {}
    for b in batches:
        x = jax.random.normal(jax.random.key(1), (b, 3, 224, 224))
        out[b] = timeit(lambda: fn(params, x), iters=iters)
    return out


def run(quick: bool = True):
    batches = (1, 2, 4) if quick else (1, 2, 4, 8, 16)
    iters = 3 if quick else 8
    t_eval = _eval_time_vs_batch(batches, iters)
    rows = []
    base_b = max(batches)
    # weak scaling: per-device batch fixed at base_b → flat by construction
    for n in (1, 4, 16, 64, 256):
        rows.append(Row(
            f"fig8/weak/{n}dev", t_eval[base_b] * 1e6,
            f"per_dev_batch={base_b};collective_bytes=0;flat=true"))
    # strong scaling: global batch fixed at base_b × 16; per-device shrinks.
    # Paper's observation: eval efficiency degrades at small batch but the
    # per-device transfer shrinks 1/N, so the TOTAL stays near-linear —
    # reproduce with the measured per-image transfer cost folded in.
    global_b = base_b * 16
    img_bytes = 3 * 224 * 224 * 4
    from .common import v5e_transfer_time
    # measured host transfer time per image (send+retrieve), amortized:
    t_xfer_per_img = 2 * 0.45e-3      # ~0.45 ms/op measured in fig4 regime
    for n in (16, 32, 64, 128, 256):
        per = max(1, global_b // n)
        nearest = min(batches, key=lambda b: abs(b - per))
        t_ev = t_eval[nearest] * per / nearest
        t_tr = t_xfer_per_img * per
        t_total = t_ev + t_tr
        base_total = (t_eval[base_b] + t_xfer_per_img * base_b) \
            * global_b / base_b
        eff_ev = (t_eval[base_b] * global_b / base_b) / (n * t_ev)
        eff_tot = base_total / (n * t_total)
        rows.append(Row(
            f"fig8/strong/{n}dev", t_total * 1e6,
            f"per_dev_batch={per};eval_eff={eff_ev:.2f};"
            f"total_eff={eff_tot:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
