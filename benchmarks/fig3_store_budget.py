"""Fig. 3 analogue: store resource budget sweep.

Paper: send/retrieve cost vs CPU cores given to the co-located DB (flat for
≥8 cores; KeyDB OK at 4).  TPU translation: the co-located store's resource
is HBM (slots per chip) — we sweep table capacity and compare the ``ring``
and ``hash`` engines (the Redis-vs-KeyDB axis), reporting the per-op cost
and the HBM footprint the budget buys.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Client, StoreServer, TableSpec
from repro.core.store import make_key, table_bytes

from .common import Row, timeit


def run(quick: bool = True):
    elems = 256 * 1024 // 4                    # paper's 256KB per rank
    caps = (4, 8, 16, 32) if quick else (4, 8, 16, 32, 64, 128)
    rows = []
    data = jax.random.normal(jax.random.key(0), (elems,))
    for engine in ("ring", "hash"):
        for cap in caps:
            server = StoreServer()
            server.create_table(TableSpec("t", shape=(elems,), capacity=cap,
                                          engine=engine))
            client = Client(server)
            step = [0]

            def send():
                step[0] += 1
                server.put("t", make_key(0, step[0] % 512), data)
                return data

            t_send = timeit(send, iters=8 if quick else 40)

            def retrieve():
                v, _ = server.get("t", make_key(0, step[0] % 512))
                return v

            t_retr = timeit(retrieve, iters=8 if quick else 40)
            hbm = table_bytes(server.spec("t"))
            rows.append(Row(
                f"fig3/{engine}/cap{cap}/send", t_send * 1e6,
                f"hbm_mb={hbm/2**20:.1f};engine={engine}"))
            rows.append(Row(
                f"fig3/{engine}/cap{cap}/retrieve", t_retr * 1e6,
                f"hbm_mb={hbm/2**20:.1f};engine={engine}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
