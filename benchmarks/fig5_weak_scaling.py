"""Fig. 5: weak scaling of send/retrieve — THE paper headline.

Paper: co-located deployment is perfectly flat to 448 nodes; clustered
cost grows ∝ ranks for a fixed DB and flattens only when the DB is sharded
proportionally.

CPU-container methodology (one core executes all simulated devices, so
wall-clock cannot show flat scaling directly — the structure can):

1. *structural proof*: lower the co-located put at mesh sizes 16→256 and
   count collective bytes in the compiled HLO — exactly 0 at every size,
   i.e. cost-per-device is size-independent on hardware.  The clustered
   staging reshard shows nonzero, growing collective bytes.
2. *modeled curves* on v5e constants: per-rank 256KB per step;
   co-located t = 2·msg/HBM_bw (flat); clustered-fixed-DB
   t = fan_in·msg/(links·ICI_bw) (∝ ranks); clustered-scaled-DB flat at
   the 8:1 fan-in the paper uses.
3. *measured* single-device per-op cost as the absolute anchor.
"""

from __future__ import annotations

import json

from .common import HW, Row, v5e_transfer_time


MSG = 256 * 1024     # paper: 256KB per rank
RANKS_PER_NODE = 24


def structural_rows(quick: bool = True):
    """Run the zero-collective lowering proof in a subprocess."""
    import os
    import subprocess
    import sys
    import textwrap
    sizes = "(16, 64, 256)" if quick else "(16, 64, 128, 256)"
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
        import jax, jax.numpy as jnp, json
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import store as S
        from repro.core.store import TableSpec
        from repro.analysis.hlo import collective_bytes
        out = []
        for n in {sizes}:
            devs = jax.devices()[:n]
            mesh = Mesh(devs, ("data",))
            elems = {MSG} // 4
            spec = TableSpec("f", shape=(n, elems), capacity=4, engine="ring")
            slab_sh = NamedSharding(mesh, P(None, "data", None))
            elem_sh = NamedSharding(mesh, P("data", None))
            st_abs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                               sharding=a.sharding),
                S.init_table(spec, slab_sh))
            val = jax.ShapeDtypeStruct((n, elems), jnp.float32,
                                       sharding=elem_sh)
            key = jax.ShapeDtypeStruct((), jnp.uint32)
            txt = jax.jit(lambda st, k, v: S.put(spec, st, k, v),
                          donate_argnums=0).lower(st_abs, key, val) \\
                .compile().as_text()
            colo = collective_bytes(txt).get("total", 0)
            txt2 = jax.jit(lambda v: v,
                           out_shardings=NamedSharding(mesh, P())) \\
                .lower(val).compile().as_text()
            clus = collective_bytes(txt2).get("total", 0)
            out.append((n, colo, clus))
        print("RESULT", json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=560, env=env)
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            for n, colo, clus in json.loads(line.split(" ", 1)[1]):
                rows.append(Row(
                    f"fig5/structural/{n}dev", 0.0,
                    f"colocated_collective_bytes={colo};"
                    f"clustered_collective_bytes={clus}"))
    if not rows:
        rows.append(Row("fig5/structural/error", 0.0,
                        proc.stderr.strip().splitlines()[-1][:120]
                        if proc.stderr else "no output"))
    return rows


def modeled_rows(quick: bool = True):
    nodes = (1, 4, 16, 64, 256, 448)
    rows = []
    for n in nodes:
        ranks = n * RANKS_PER_NODE
        t_colo = v5e_transfer_time(2 * MSG, 0)
        # fixed DB: every rank's message funnels into one shard
        t_fixed = v5e_transfer_time(2 * MSG, ranks * MSG)
        # scaled DB (paper: 448 sim : 16 db ≈ 28:1 … we use their 8:1 run)
        t_scaled = v5e_transfer_time(2 * MSG, 8 * MSG)
        rows.append(Row(f"fig5/model/{n}nodes", t_colo * 1e6,
                        f"ranks={ranks};"
                        f"colocated_us={t_colo*1e6:.1f};"
                        f"clustered_fixed_db_us={t_fixed*1e6:.1f};"
                        f"clustered_scaled_db_us={t_scaled*1e6:.1f}"))
    return rows


def measured_anchor():
    import jax
    from repro.core import StoreServer, TableSpec
    from repro.core.store import make_key
    from .common import timeit
    elems = MSG // 4
    server = StoreServer()
    server.create_table(TableSpec("t", shape=(elems,), capacity=4,
                                  engine="ring"))
    data = jax.random.normal(jax.random.key(0), (elems,))
    step = [0]

    def send():
        step[0] += 1
        server.put("t", make_key(0, step[0] % 512), data)
        return data

    t = timeit(send, iters=10)
    return [Row("fig5/measured_anchor/send_256KB", t * 1e6,
                "host_cpu=1core")]


def run(quick: bool = True):
    return measured_anchor() + structural_rows(quick) + modeled_rows(quick)


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
