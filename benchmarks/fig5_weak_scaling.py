"""Fig. 5: weak scaling of send/retrieve — THE paper headline.

Paper: co-located deployment is perfectly flat to 448 nodes; clustered
cost grows ∝ ranks for a fixed DB and flattens only when the DB is sharded
proportionally.

CPU-container methodology (one core executes all simulated devices, so
wall-clock cannot show flat scaling directly — the structure can):

1. *structural proof*: lower the co-located put at mesh sizes 16→256 and
   count collective bytes in the compiled HLO — exactly 0 at every size,
   i.e. cost-per-device is size-independent on hardware.  The clustered
   staging reshard shows nonzero, growing collective bytes.
2. *modeled curves* on v5e constants: per-rank 256KB per step;
   co-located t = 2·msg/HBM_bw (flat); clustered-fixed-DB
   t = fan_in·msg/(links·ICI_bw) (∝ ranks); clustered-scaled-DB flat at
   the 8:1 fan-in the paper uses.
3. *measured* single-device per-op cost as the absolute anchor.
4. **measured clustered fan-in curve** (the paper's clustered line, run
   for real): the SAME ~10-line ``InSituSession`` declaration — a fused
   producer streaming 256KB snapshots into a ``Clustered`` store — at a
   >= 3-point sweep of producer:db device ratios (``split_devices``),
   each cell in a fresh subprocess with forced host devices.  Measures
   producer steps/s AND the structural clustered claim: exactly ONE
   cross-mesh staged transfer per ``capture_scan`` chunk
   (``stats()["staged_transfers"]`` == ``plan.explain()`` prediction),
   with the two-slot overlap staging pipeline ON.  A serial-staging
   baseline cell (``overlap=False``) at the most contended ratio gives
   the same-run overlap-vs-serial pair, and the sweep fits the plan's
   ``ContentionModel`` (steps/s vs fan-in, OLS over the measured cells)
   whose per-cell throughput predictions are folded back into the JSON.
   Writes ``BENCH_weak_scaling.json``; ``tools/check_bench.py`` gates
   staged/chunk == 1 and exact op counts (hard), the fan-in and
   overlap-vs-serial throughput ratios, the contention-model fit
   residual, and each cell's predicted-vs-measured throughput (bands).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from .common import HW, Row, v5e_transfer_time


MSG = 256 * 1024     # paper: 256KB per rank
RANKS_PER_NODE = 24

_CLUSTERED_CHILD = """
    import json, sys
    import jax, jax.numpy as jnp
    from repro.core import TableSpec, make_clustered_1d
    from repro.core import store as S
    from repro.insitu import InSituSession, Producer

    db_fraction, steps, chunk, msg, overlap = (
        float(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3]),
        int(sys.argv[4]), bool(int(sys.argv[5])))
    elems = msg // 4                         # 256KB float32 per snapshot
    snap = jax.random.normal(jax.random.key(0), (elems,))

    def step(carry, rank, t):
        return carry + 1.0, S.make_key(rank, t), snap * carry

    # the whole clustered scenario is one declaration: a fused producer
    # streaming into a store on dedicated devices; ``overlap`` toggles
    # the two-slot staging pipeline vs the serial stage-then-insert path
    dep = make_clustered_1d(db_fraction=db_fraction, overlap=overlap)

    def one_run():
        session = InSituSession(
            tables=[TableSpec("field", shape=(elems,), capacity=16,
                              engine="ring")],
            components=[Producer(step, table="field", steps=steps,
                                 carry=jnp.zeros(()), emit_every=1,
                                 chunk=chunk)],
            deployment=dep)
        plan = session.plan()
        res = session.run(plan=plan, sequential=True, max_wall_s=600)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        return plan, res

    # best-of-2 in ONE process: run 1 pays residual warmup, run 2 (fresh
    # server, warm jit cache) gives the clean timing — millisecond-scale
    # chunk walls on a shared CPU need the repeat to gate reliably
    walls = []
    for _ in range(2):
        plan, res = one_run()
        t = res.run.timers
        walls.append(t.total("equation_solution") + t.total("send"))
    stats = res.server.stats()
    wall = min(walls)
    chunks = -(-steps // chunk)
    n_clients = len(dep.client_mesh.devices.ravel())
    n_db = len(dep.db_mesh.devices.ravel())
    print(json.dumps({
        "fan_in": dep.fan_in,
        "clients": n_clients,
        "db": n_db,
        "devices": len(jax.devices()),
        "steps": steps,
        "chunks": chunks,
        "overlap": overlap,
        "step_bytes": msg,
        "steps_per_s": steps / max(wall, 1e-9),
        "dispatch_s": t.total("send") / max(1, stats["op_count"]),
        "staged_transfers": stats["staged_transfers"],
        "predicted_staged": plan.staged_transfers,
        "staged_per_chunk": stats["staged_transfers"] / chunks,
        "op_count": stats["op_count"],
        "predicted_ops": plan.store_dispatches,
    }))
"""


def structural_rows(quick: bool = True):
    """Run the zero-collective lowering proof in a subprocess."""
    sizes = "(16, 64, 256)" if quick else "(16, 64, 128, 256)"
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
        import jax, jax.numpy as jnp, json
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import store as S
        from repro.core.store import TableSpec
        from repro.analysis.hlo import collective_bytes
        out = []
        for n in {sizes}:
            devs = jax.devices()[:n]
            mesh = Mesh(devs, ("data",))
            elems = {MSG} // 4
            spec = TableSpec("f", shape=(n, elems), capacity=4, engine="ring")
            slab_sh = NamedSharding(mesh, P(None, "data", None))
            elem_sh = NamedSharding(mesh, P("data", None))
            st_abs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                               sharding=a.sharding),
                S.init_table(spec, slab_sh))
            val = jax.ShapeDtypeStruct((n, elems), jnp.float32,
                                       sharding=elem_sh)
            key = jax.ShapeDtypeStruct((), jnp.uint32)
            txt = jax.jit(lambda st, k, v: S.put(spec, st, k, v),
                          donate_argnums=0).lower(st_abs, key, val) \\
                .compile().as_text()
            colo = collective_bytes(txt).get("total", 0)
            txt2 = jax.jit(lambda v: v,
                           out_shardings=NamedSharding(mesh, P())) \\
                .lower(val).compile().as_text()
            clus = collective_bytes(txt2).get("total", 0)
            out.append((n, colo, clus))
        print("RESULT", json.dumps(out))
    """)
    proc = _run_py(code, env_extra={})
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            for n, colo, clus in json.loads(line.split(" ", 1)[1]):
                rows.append(Row(
                    f"fig5/structural/{n}dev", 0.0,
                    f"colocated_collective_bytes={colo};"
                    f"clustered_collective_bytes={clus}"))
    if not rows:
        rows.append(Row("fig5/structural/error", 0.0,
                        proc.stderr.strip().splitlines()[-1][:120]
                        if proc.stderr else "no output"))
    return rows


def _run_py(code: str, argv: list[str] = (), env_extra: dict | None = None):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    if env_extra:
        env.update(env_extra)
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code), *argv],
        capture_output=True, text=True, timeout=560, env=env)


def _clustered_cell(db_fraction: float, steps: int, chunk: int,
                    devices: int, overlap: bool = True) -> dict:
    """One measured clustered fan-in cell in a fresh subprocess (forcing
    host devices must precede the first jax call; fresh processes keep
    the cells' timings free of each other's compile caches)."""
    proc = _run_py(
        _CLUSTERED_CHILD,
        argv=[str(db_fraction), str(steps), str(chunk), str(MSG),
              str(int(overlap))],
        env_extra={"XLA_FLAGS":
                   f"--xla_force_host_platform_device_count={devices}"})
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig5 clustered cell (db_fraction={db_fraction}) failed:\n"
            f"{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _fanin_comparison(cells: list[dict]) -> dict | None:
    """Lowest vs highest fan-in cell of the sweep — the same-run band
    ``tools/check_bench.py`` gates (producer work is identical across
    cells, so on shared hardware the ratio isolates the fan-in cost)."""
    if len(cells) < 2:
        return None
    lo = min(cells, key=lambda c: c["fan_in"])
    hi = max(cells, key=lambda c: c["fan_in"])
    if lo["fan_in"] == hi["fan_in"]:
        return None
    return {
        "fan_in_lo": lo["fan_in"],
        "fan_in_hi": hi["fan_in"],
        "throughput_ratio": hi["steps_per_s"] / lo["steps_per_s"],
        "staged_per_chunk_max": max(c["staged_per_chunk"] for c in cells),
    }


def _fit_contention(cells: list[dict]) -> dict | None:
    """Fit the plan's :class:`repro.insitu.plan.ContentionModel` from the
    measured sweep and fold its per-cell throughput predictions back into
    the cells (``predicted_steps_per_s`` — the band
    ``tools/check_bench.py`` gates).  The serialized model is what a user
    hands back to ``Clustered.cost_model`` to turn ``plan.explain()``
    into a throughput prediction and the chunk autotuner on."""
    from repro.insitu.plan import ContentionModel
    if len({c["fan_in"] for c in cells}) < 2:
        return None
    t_dispatch = sum(c["dispatch_s"] for c in cells) / len(cells)
    model = ContentionModel.fit(cells)
    model = ContentionModel(t_base=model.t_base, k_fanin=model.k_fanin,
                            step_bytes=model.step_bytes,
                            t_dispatch=t_dispatch)
    for c in cells:
        c["predicted_steps_per_s"] = model.predict_steps_per_s(c["fan_in"])
    return {
        "t_base": model.t_base,
        "k_fanin": model.k_fanin,
        "step_bytes": model.step_bytes,
        "t_dispatch": model.t_dispatch,
        "fit_residual": model.residual(cells),
    }


def clustered_fanin(quick: bool = True, smoke: bool = False) -> dict:
    """The measured clustered fan-in contention sweep (see module doc)."""
    if smoke or quick:
        devices, steps, chunk = 6, 192, 16
        # 3:3, 4:2, 5:1 -> fan_in 1, 2, 5 (>= 3 points fits the model)
        fractions = (0.5, 1 / 3, 1 / 6)
    else:
        devices, steps, chunk = 8, 256, 16
        fractions = (0.5, 0.25, 0.125)  # 4:4, 6:2, 7:1 -> fan_in 1, 3, 7
    cells = [_clustered_cell(f, steps, chunk, devices) for f in fractions]
    # serial staging baseline at the most contended ratio: identical
    # producer work with the two-slot pipeline OFF — the same-run pair
    # check_bench gates the overlap win against
    serial = _clustered_cell(fractions[-1], steps, chunk, devices,
                             overlap=False)
    hi = cells[-1]
    return {
        "bench": "weak_scaling",
        "api": "insitu_session",
        "devices": devices,
        "steps": steps,
        "chunk": chunk,
        "cells": cells,
        "contention_model": _fit_contention(cells),
        "serial_baseline": serial,
        "overlap_comparison": {
            "fan_in": hi["fan_in"],
            "overlap_steps_per_s": hi["steps_per_s"],
            "serial_steps_per_s": serial["steps_per_s"],
            "throughput_ratio": hi["steps_per_s"] / serial["steps_per_s"],
        },
        "fanin_comparison": _fanin_comparison(cells),
    }


def modeled_rows(quick: bool = True):
    nodes = (1, 4, 16, 64, 256, 448)
    rows = []
    for n in nodes:
        ranks = n * RANKS_PER_NODE
        t_colo = v5e_transfer_time(2 * MSG, 0)
        # fixed DB: every rank's message funnels into one shard
        t_fixed = v5e_transfer_time(2 * MSG, ranks * MSG)
        # scaled DB (paper: 448 sim : 16 db ≈ 28:1 … we use their 8:1 run)
        t_scaled = v5e_transfer_time(2 * MSG, 8 * MSG)
        rows.append(Row(f"fig5/model/{n}nodes", t_colo * 1e6,
                        f"ranks={ranks};"
                        f"colocated_us={t_colo*1e6:.1f};"
                        f"clustered_fixed_db_us={t_fixed*1e6:.1f};"
                        f"clustered_scaled_db_us={t_scaled*1e6:.1f}"))
    return rows


def measured_anchor():
    import jax
    from repro.core import StoreServer, TableSpec
    from repro.core.store import make_key
    from .common import timeit
    elems = MSG // 4
    server = StoreServer()
    server.create_table(TableSpec("t", shape=(elems,), capacity=4,
                                  engine="ring"))
    data = jax.random.normal(jax.random.key(0), (elems,))
    step = [0]

    def send():
        step[0] += 1
        server.put("t", make_key(0, step[0] % 512), data)
        return data

    t = timeit(send, iters=10)
    return [Row("fig5/measured_anchor/send_256KB", t * 1e6,
                "host_cpu=1core")]


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True, smoke: bool = False):
    fanin = clustered_fanin(quick=quick, smoke=smoke)
    if write_json:
        path = Path(json_path) if json_path \
            else Path("BENCH_weak_scaling.json")
        path.write_text(json.dumps(fanin, indent=2) + "\n")

    rows = []
    for c in fanin["cells"]:
        pred = c.get("predicted_steps_per_s")
        rows.append(Row(
            f"fig5/clustered/fanin{c['fan_in']}",
            1e6 / c["steps_per_s"],
            f"clients={c['clients']};db={c['db']};"
            f"steps_per_s={c['steps_per_s']:.1f};"
            + (f"predicted_steps_per_s={pred:.1f};" if pred else "")
            + f"staged_per_chunk={c['staged_per_chunk']:.2f}"))
    ocmp = fanin.get("overlap_comparison")
    if ocmp:
        rows.append(Row(
            f"fig5/clustered/overlap_vs_serial_fanin{ocmp['fan_in']}",
            ocmp["throughput_ratio"],
            f"overlap={ocmp['overlap_steps_per_s']:.1f};"
            f"serial={ocmp['serial_steps_per_s']:.1f}"))
    if smoke:
        return rows
    return (measured_anchor() + structural_rows(quick) + rows
            + modeled_rows(quick))


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
