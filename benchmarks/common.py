"""Benchmark plumbing: timing, CSV emission, and the v5e transfer model.

Methodology (CPU container, per the harness): wall-clock numbers are real
measurements on the host device; *scaling* curves additionally report the
structural quantities extracted from compiled HLO (collective bytes per
device — zero for the co-located deployment) and the modeled v5e transfer
time  t = max(bytes_local / HBM_bw, bytes_ici / (links·ICI_bw))  using the
hardware constants in ``repro.launch.mesh.HW``.  Every CSV row is
``name,us_per_call,derived`` (derived: free-form ``k=v;k=v`` pairs).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax

from repro.launch.mesh import HW

__all__ = ["timeit", "Row", "emit", "v5e_transfer_time", "HW"]


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def emit(rows):
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    return rows


def timeit(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall time per call (seconds), blocking on the result."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def v5e_transfer_time(local_bytes: float, ici_bytes: float) -> float:
    """Modeled per-device transfer time on v5e (seconds)."""
    t_hbm = local_bytes / HW["hbm_bytes_per_s"]
    t_ici = ici_bytes / (HW["ici_links"] * HW["ici_bytes_per_s_per_link"])
    return max(t_hbm, t_ici)
