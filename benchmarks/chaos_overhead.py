"""Fault-machinery overhead: what exactly-once + recovery actually cost.

Three measured cells over the same producer + fused-trainer session:

* ``disarmed`` — no ``FaultPlan``: the pre-chaos fast path (no chunk ids,
  no WAL, no injector consults);
* ``armed`` — an *empty* ``FaultPlan``: the logged exactly-once path
  (chunk acks + write-ahead log + checkpoint saves) with zero faults —
  the steady-state tax of being recoverable;
* ``faulted`` — a seeded plan injecting transient unavailability, a
  dropped chunk, a producer crash and a store restart: the recovery tax,
  with the plan's predicted retry/replay overhead reported next to the
  measured ``stats()`` counters (they must match exactly — the chaos
  test grid asserts it; the bench just prints the same parity).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Row


def _session(faults, steps: int, epochs: int):
    from repro.core import TableSpec
    from repro.core import store as S
    from repro.insitu import InSituSession, Producer, TrainerConsumer
    from repro.ml import autoencoder as ae
    from repro.ml import trainer as tr
    from repro.sim import flatplate as fp

    fcfg = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
    coords = fp.grid_coords(fcfg)
    snaps = jnp.stack([fp.snapshot(fcfg, jax.random.key(0), t)
                       for t in range(8)])

    def step(carry, rank, t):
        return carry, S.make_key(rank, t), snaps[t % 8]

    cfg = tr.TrainerConfig(
        ae=ae.AEConfig(n_points=fcfg.n_points, mode="ref", latent=4,
                       internal=4, blocks=1, mlp_width=8, mlp_depth=2),
        epochs=epochs, gather=4, batch_size=2, lr=1e-3)
    return InSituSession(
        tables=[TableSpec("field", shape=(4, fcfg.n_points), capacity=16,
                          engine="ring")],
        components=[
            Producer(step, table="field", steps=steps, ranks=1,
                     carry=jnp.zeros(()), chunk=4),
            TrainerConsumer(cfg, coords)],
        faults=faults)


def run(quick: bool = True):
    from repro.core.faults import FaultEvent, FaultPlan, RetryPolicy

    steps = 16 if quick else 64
    epochs = 3 if quick else 10
    retry = RetryPolicy(interval=1e-4, max_interval=1e-3)
    chaos = FaultPlan(events=(
        FaultEvent("unavailable", verb="capture", at=1, count=2),
        FaultEvent("drop_chunk", table="field", at=2),
        FaultEvent("crash", component="producer", at=2),
        FaultEvent("snapshot", table="field", at=2),
        FaultEvent("restart", table="field", at=3),
    ), retry=retry)
    cells = (("disarmed", None),
             ("armed", FaultPlan(events=(), retry=retry)),
             ("faulted", chaos))

    rows = []
    walls = {}
    for name, plan in cells:
        sess = _session(plan, steps, epochs)
        splan = sess.plan()
        t0 = time.perf_counter()
        res = sess.run(plan=splan, sequential=True, max_wall_s=600)
        walls[name] = time.perf_counter() - t0
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        stats = res.server.stats()
        per_step = walls[name] / steps
        rows.append(Row(
            f"chaos/{name}/wall", per_step * 1e6,
            f"wall_s={walls[name]:.3f};ops={stats['op_count']};"
            f"predicted_ops={splan.store_dispatches};"
            f"retries={stats['retries']};"
            f"recoveries={stats['recoveries']};"
            f"faults={stats['faults_injected']}"))
        assert stats["op_count"] == splan.store_dispatches
    rows.append(Row(
        "chaos/armed_vs_disarmed", walls["armed"] * 1e6,
        f"ratio={walls['armed'] / walls['disarmed']:.3f};"
        f"meaning=exactly-once_tax"))
    rows.append(Row(
        "chaos/faulted_vs_armed", walls["faulted"] * 1e6,
        f"ratio={walls['faulted'] / walls['armed']:.3f};"
        f"meaning=recovery_tax"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=True))
