"""Tables 1-2: end-to-end in-situ training overhead breakdown.

Paper: on 40 nodes (960 PHASTA ranks + 160 GPUs), client init + metadata +
data send total ≪1% of the PDE integration time, and the consumer's data
retrieval ~1% of training time.  We run the full workflow (flat-plate
producer + QuadConv-AE consumer coupled through the co-located store) and
report the same component table + ratios.
"""

from __future__ import annotations

import contextlib
import io

from .common import Row


def run(quick: bool = True):
    from repro.launch.insitu import run as insitu_run
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        # compute_s emulates the PDE-integration cost like the paper's
        # Fortran reproducer (the synthetic producer itself is ~9 ms/step,
        # 5 orders cheaper than PHASTA — ratios need the stand-in).
        res = insitu_run(epochs=6 if quick else 40,
                         sim_steps=60 if quick else 300,
                         compute_s=0.25 if quick else 0.5,
                         verbose=False)
    t = res.timers
    rows = []
    for name in ("client_init", "metadata", "send", "retrieve",
                 "equation_solution", "train", "total_training",
                 "model_eval"):
        if t.total(name) or name in t.summary():
            s = t.stats(name)
            rows.append(Row(f"table12/{name}", s.mean * 1e6,
                            f"total_s={s.total:.4f};std_us={s.std*1e6:.1f};"
                            f"count={s.count}"))
    sol = t.total("equation_solution")
    send_over = (t.total("send") + t.total("client_init")
                 + 0.0) / sol if sol else 0.0
    train = t.total("total_training")
    retr_over = t.total("retrieve") / train if train else 0.0
    meta_over = t.total("metadata") / train if train else 0.0
    rows.append(Row("table12/overhead_send_vs_solver", send_over * 1e6,
                    f"ratio={send_over:.4f};paper=<<1%"))
    rows.append(Row("table12/overhead_retrieve_vs_training",
                    retr_over * 1e6, f"ratio={retr_over:.4f};paper=~1%"))
    rows.append(Row("table12/overhead_metadata_vs_training",
                    meta_over * 1e6, f"ratio={meta_over:.4f};paper=4.4%"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
