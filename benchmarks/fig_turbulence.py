"""Distributed CFD producer: the halo-exchange sharded solver as a
data-plane workload.

The `sim.distributed` finite-difference solver is domain-decomposed over
a ``space`` mesh axis inside one ``shard_map`` (width-w halo exchange
via ``lax.ppermute``), and its snapshots enter the store as
**element-sharded puts emitted directly from the shards** — the
``capture_scan_sharded`` tier.  This bench runs the decaying-turbulence
workload end to end on a 2-D ``(slab, space)`` db mesh
(``make_clustered_2d``) at a sweep of ``space``-shard counts, each cell
a fresh subprocess with forced host devices, and measures:

* producer steps/s (solver + shard-local put + cross-mesh staging);
* the structural clustered claim: exactly ONE staged transfer per
  ``capture_scan`` chunk, matching ``plan.explain()`` exactly;
* the physics claim: kinetic energy decays and the projected field
  stays near-divergence-free through the store round-trip (the stored
  snapshot itself is checked, not solver-internal state).

Writes ``BENCH_turbulence.json``; ``tools/check_bench.py`` gates
staged/chunk == 1, measured == predicted (hard), physics (hard), and
the sharded:unsharded throughput ratio (band).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from .common import Row


_CELL_CHILD = """
    import json, sys
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import TableSpec, make_clustered_2d
    from repro.core import store as S
    from repro.insitu import InSituSession, Producer
    from repro.sim import distributed as fd

    shards, steps, chunk, n, jacobi = map(int, sys.argv[1:6])
    cfg = fd.FDConfig(n=n, nu=2e-3, dt=1e-3, jacobi_iters=jacobi)

    # the whole distributed-CFD scenario is one declaration: a sharded
    # solver emitting element-sharded snapshots into a 2-D db mesh
    dep = make_clustered_2d(P(None, "space", None), db_fraction=0.5,
                            slab_shards=1)
    step_fn, state0, elem_sharding = fd.make_producer(
        cfg, dep.client_mesh, init="decaying_turbulence",
        key=jax.random.key(7))
    session = InSituSession(
        tables=[TableSpec("field", shape=(2, n, n), capacity=16,
                          engine="ring")],
        components=[Producer(step_fn, table="field", steps=steps,
                             carry=state0, emit_every=1, chunk=chunk,
                             elem_sharding=elem_sharding)],
        deployment=dep)
    plan = session.plan()
    res = session.run(plan=plan, sequential=True, max_wall_s=600)
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    stats = res.server.stats()
    t = res.run.timers
    wall = t.total("equation_solution") + t.total("send")
    chunks = -(-steps // chunk)

    e0 = float(fd.energy(state0))
    snap, found = res.server.get("field", S.make_key(0, steps - 1))
    assert bool(found), "final snapshot missing from the store"
    final = fd.FDState(u=snap[0], v=snap[1],
                       t=jnp.zeros(()), step=jnp.zeros((), jnp.int32))
    print(json.dumps({
        "space_shards": shards,
        "devices": len(jax.devices()),
        "grid": n,
        "steps": steps,
        "chunks": chunks,
        "steps_per_s": steps / max(wall, 1e-9),
        "bytes_per_chunk": chunk * 2 * n * n * 4,
        "staged_transfers": stats["staged_transfers"],
        "predicted_staged": plan.staged_transfers,
        "staged_per_chunk": stats["staged_transfers"] / chunks,
        "op_count": stats["op_count"],
        "predicted_ops": plan.store_dispatches,
        "energy_initial": e0,
        "energy_final": float(fd.energy(final)),
        "divergence_max": float(fd.max_divergence(cfg, final)),
    }))
"""


def _run_py(code: str, argv: list[str] = (), env_extra: dict | None = None):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    if env_extra:
        env.update(env_extra)
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code), *argv],
        capture_output=True, text=True, timeout=560, env=env)


def _shard_cell(shards: int, steps: int, chunk: int, n: int,
                jacobi: int) -> dict:
    """One measured space-shard cell in a fresh subprocess (forcing host
    devices must precede the first jax call; fresh processes keep the
    cells' timings free of each other's compile caches).  Total device
    count is 2*shards so the client mesh is always exactly ``shards``
    wide and the db side matches it (fan-in 1 at every cell — the cost
    under test is the halo exchange + shard-local put, not fan-in)."""
    proc = _run_py(
        _CELL_CHILD,
        argv=[str(shards), str(steps), str(chunk), str(n), str(jacobi)],
        env_extra={"XLA_FLAGS":
                   f"--xla_force_host_platform_device_count={2 * shards}"})
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig_turbulence cell (shards={shards}) failed:\n"
            f"{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _shards_comparison(cells: list[dict]) -> dict | None:
    """Unsharded vs widest cell of the sweep — the same-run band
    ``tools/check_bench.py`` gates (the grid is fixed, so on shared
    hardware the ratio isolates halo-exchange + sharded-put cost)."""
    if len(cells) < 2:
        return None
    lo = min(cells, key=lambda c: c["space_shards"])
    hi = max(cells, key=lambda c: c["space_shards"])
    if lo["space_shards"] == hi["space_shards"]:
        return None
    ratio = hi["steps_per_s"] / lo["steps_per_s"]
    return {
        "shards_lo": lo["space_shards"],
        "shards_hi": hi["space_shards"],
        "devices_lo": lo["devices"],
        "devices_hi": hi["devices"],
        "throughput_ratio": ratio,
        # one container core executes every simulated host device
        # serially, so the widest cell pays emulation cost ~devices;
        # normalizing by the device factor recovers the per-device claim
        "throughput_ratio_per_device": ratio * hi["devices"]
                                             / lo["devices"],
        "staged_per_chunk_max": max(c["staged_per_chunk"] for c in cells),
        "energy_final_spread": abs(hi["energy_final"]
                                   - lo["energy_final"]),
        "divergence_spread": abs(hi["divergence_max"]
                                 - lo["divergence_max"]),
    }


def shard_sweep(quick: bool = True, smoke: bool = False) -> dict:
    """The measured space-shard sweep (see module doc)."""
    if smoke or quick:
        steps, chunk, n, jacobi = 48, 16, 32, 8
        shard_counts = (1, 2)
    else:
        steps, chunk, n, jacobi = 128, 16, 64, 32
        shard_counts = (1, 2, 4)
    cells = [_shard_cell(s, steps, chunk, n, jacobi)
             for s in shard_counts]
    return {
        "bench": "turbulence",
        "api": "insitu_session",
        "steps": steps,
        "chunk": chunk,
        "grid": n,
        "jacobi_iters": jacobi,
        "cells": cells,
        "shards_comparison": _shards_comparison(cells),
    }


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True, smoke: bool = False):
    sweep = shard_sweep(quick=quick, smoke=smoke)
    if write_json:
        path = Path(json_path) if json_path \
            else Path("BENCH_turbulence.json")
        path.write_text(json.dumps(sweep, indent=2) + "\n")

    rows = []
    for c in sweep["cells"]:
        rows.append(Row(
            f"turbulence/shards{c['space_shards']}",
            1e6 / c["steps_per_s"],
            f"grid={c['grid']};steps_per_s={c['steps_per_s']:.1f};"
            f"staged_per_chunk={c['staged_per_chunk']:.2f};"
            f"div_max={c['divergence_max']:.2e}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
