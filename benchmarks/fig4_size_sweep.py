"""Fig. 4: send/retrieve cost and throughput vs message size.

Paper: ~constant latency below 256KB (fixed per-request cost), linear time
/ flat throughput from 256KB to 16MB, for both deployments.  Here:
measured wall time per op on the host device across 64KB → 16MB, plus the
modeled v5e cost for the co-located (HBM copy) and clustered (ICI hop)
paths at the same sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Client, StoreServer, TableSpec
from repro.core.store import make_key

from .common import Row, timeit, v5e_transfer_time


SIZES_KB = (16, 64, 256, 1024, 4096, 16384)


def run(quick: bool = True):
    sizes = SIZES_KB[:4] if quick else SIZES_KB
    rows = []
    for kb in sizes:
        elems = kb * 1024 // 4
        server = StoreServer()
        server.create_table(TableSpec("t", shape=(elems,), capacity=4,
                                      engine="ring"))
        data = jax.random.normal(jax.random.key(0), (elems,))
        jax.block_until_ready(data)
        step = [0]

        def send():
            step[0] += 1
            server.put("t", make_key(0, step[0] % 512), data)
            return data

        t_send = timeit(send, iters=6 if quick else 40)

        def retrieve():
            v, _ = server.get("t", make_key(0, step[0] % 512))
            return v

        t_retr = timeit(retrieve, iters=6 if quick else 40)
        nbytes = elems * 4
        tp_send = nbytes / t_send / 2**20
        tp_retr = nbytes / t_retr / 2**20
        # modeled v5e: co-located = pure HBM copy; clustered = ICI hop
        t_colo = v5e_transfer_time(2 * nbytes, 0)         # rd + wr
        t_clus = v5e_transfer_time(2 * nbytes, nbytes)
        rows.append(Row(f"fig4/send/{kb}KB", t_send * 1e6,
                        f"MBps={tp_send:.0f};v5e_colo_us={t_colo*1e6:.1f};"
                        f"v5e_clustered_us={t_clus*1e6:.1f}"))
        rows.append(Row(f"fig4/retrieve/{kb}KB", t_retr * 1e6,
                        f"MBps={tp_retr:.0f}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
