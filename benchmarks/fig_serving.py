"""Serving bench: store-backed continuous batching vs the three-step
protocol, plus model hot-swap latency.

The serving plane's analogue of fig9's pipeline bench, run through the
same ~10-line ``InSituSession`` declaration: ``clients`` concurrent
inference clients submit requests into a ring request table, one
``ServingConsumer`` drains them with continuous batching (each drained
batch = ONE fused gather → model → scatter dispatch), responses land in
a results table the clients poll.

Cells (written to ``BENCH_serving.json``; ``tools/check_bench.py``
gates them):

* **requests/s vs concurrent clients** — end-to-end wall clock of the
  full submit → drain → collect session per client count, with the
  structural counters alongside: fused serve dispatches per drained
  batch (must be exactly 1.0), measured vs plan-predicted op counts and
  model swaps (must be equal — the serving form of the exactness
  contract).
* **tier comparison** (same run, same hardware): continuous batching vs
  the paper's one-at-a-time ``get → run_model → put`` three-step
  baseline at the widest client count.  The band gate holds the
  throughput ratio up: batching must not degrade to per-request costs.
* **swap latency** — publish-to-adoption time of a model hot-swap
  (``set_model`` + the loop's atomic ``bind_model``), host-side
  microbenchmark on a standing server.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from .common import Row, timeit


def _session(tier: str, clients: int, requests: int, max_batch: int):
    import jax.numpy as jnp
    from repro.core import TableSpec
    from repro.insitu import InSituSession, ServingClients, ServingConsumer

    shape = (64, 64)

    def feed(c, s):
        return jnp.full(shape, float(100 * c + s))

    capacity = max(32, 1 << (clients * requests - 1).bit_length())
    tables = [TableSpec("sreq", shape=shape, capacity=capacity,
                        engine="ring"),
              TableSpec("sres", shape=shape, capacity=capacity,
                        engine="ring")]
    comps = [
        ServingClients(feed, table="sreq", clients=clients,
                       requests=requests, submit=True, collect=False,
                       name="writers"),
        ServingConsumer("m", table="sreq", results="sres",
                        clients=clients, requests=requests,
                        max_batch=max_batch, tier=tier),
        ServingClients(feed, table="sreq", clients=clients,
                       requests=requests, submit=False, collect=True,
                       name="readers")]
    return InSituSession(components=comps, tables=tables)


def _model_fn(p, x):
    return p * x + 1.0


def _preload(server):
    # module-level fn: its identity is the fused dispatch's static jit
    # arg, so warmup compiles carry over to the timed session
    import jax.numpy as jnp
    server.set_model("m", _model_fn, jnp.asarray(2.0))


def _cell(tier: str, clients: int, requests: int, max_batch: int) -> dict:
    """One measured serving cell: an untimed warmup run primes the jit
    caches (shapes are shared across cells), then a fresh session is
    timed end to end."""
    total = clients * requests
    _session(tier, clients, requests, max_batch).run(
        sequential=True, preload=_preload, max_wall_s=600)
    sess = _session(tier, clients, requests, max_batch)
    plan = sess.plan()
    t0 = time.perf_counter()
    res = sess.run(plan=plan, sequential=True, preload=_preload,
                   max_wall_s=600)
    wall = time.perf_counter() - t0
    assert res.ok, {k: v.error for k, v in res.run.components.items()}
    stats = res.server.stats()
    serving = res.output("serving")
    serves = dict(next(e for e in plan.components
                       if e.name == "serving").dispatches).get("serve", 0)
    return {
        "tier": tier,
        "clients": clients,
        "requests": total,
        "max_batch": max_batch,
        "batches": serving.batches,
        "serve_dispatches": serves,
        "dispatches_per_batch": serves / max(1, serving.batches),
        "op_count": stats["op_count"],
        "predicted_ops": plan.store_dispatches,
        "model_swaps": stats["model_swaps"],
        "predicted_swaps": plan.model_swaps,
        "requests_per_s": total / max(wall, 1e-9),
    }


def _swap_cell() -> dict:
    """Publish-to-adoption latency of one hot-swap, on a standing
    server + loop (no requests in flight — the registry protocol cost)."""
    import jax.numpy as jnp
    from repro.core import Client, StoreServer, TableSpec
    from repro.serve.engine import ServeLoop

    server = StoreServer()
    for name in ("sreq", "sres"):
        server.create_table(TableSpec(name, shape=(64, 64), capacity=32,
                                      engine="ring"))
    loop = ServeLoop(Client(server), model_key="m", request_table="sreq",
                     response_table="sres", clients=1, requests=1,
                     max_batch=1)
    params = jnp.asarray(2.0)

    def publish_and_adopt():
        server.set_model("m", _model_fn, params)
        assert loop.maybe_swap()
        return params

    t = timeit(publish_and_adopt, iters=50)
    return {"swap_latency_us": t * 1e6, "adoptions": loop.swaps}


def run_cells(quick: bool = True, smoke: bool = False) -> dict:
    if smoke or quick:
        client_counts, requests, max_batch = (1, 4), 8, 4
    else:
        client_counts, requests, max_batch = (1, 2, 4, 8), 16, 8
    cells = [_cell("continuous_batch", k, requests, max_batch)
             for k in client_counts]
    widest = max(client_counts)
    three = _cell("three_step", widest, requests, max_batch)
    cont = next(c for c in cells if c["clients"] == widest)
    return {
        "bench": "serving",
        "api": "insitu_session",
        "requests_per_client": requests,
        "max_batch": max_batch,
        "cells": cells,
        "tier_comparison": {
            "clients": widest,
            "continuous_requests_per_s": cont["requests_per_s"],
            "three_step_requests_per_s": three["requests_per_s"],
            "throughput_ratio": (cont["requests_per_s"]
                                 / three["requests_per_s"]),
        },
        "swap": _swap_cell(),
    }


def run(quick: bool = True, json_path: str | None = None,
        write_json: bool = True, smoke: bool = False):
    data = run_cells(quick=quick, smoke=smoke)
    if write_json:
        path = Path(json_path) if json_path else Path("BENCH_serving.json")
        path.write_text(json.dumps(data, indent=2) + "\n")
    rows = []
    for c in data["cells"]:
        rows.append(Row(
            f"serving/continuous/clients{c['clients']}",
            1e6 / c["requests_per_s"],
            f"requests={c['requests']};max_batch={c['max_batch']};"
            f"requests_per_s={c['requests_per_s']:.1f};"
            f"batches={c['batches']};"
            f"dispatches_per_batch={c['dispatches_per_batch']:.2f};"
            f"swaps={c['model_swaps']}"))
    cmp = data["tier_comparison"]
    rows.append(Row(
        f"serving/three_step/clients{cmp['clients']}",
        1e6 / cmp["three_step_requests_per_s"],
        f"requests_per_s={cmp['three_step_requests_per_s']:.1f};"
        f"continuous_ratio={cmp['throughput_ratio']:.2f}"))
    rows.append(Row("serving/hot_swap", data["swap"]["swap_latency_us"],
                    f"adoptions={data['swap']['adoptions']}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
