"""END-TO-END DRIVER (paper §4): in-situ training of the QuadConv
autoencoder from a live flow simulation, then in-situ inference.

Run:  PYTHONPATH=src python examples/insitu_autoencoder.py [--epochs 150]

This is the paper's headline experiment at laptop scale:
  * producer: synthetic turbulent flat-plate snapshots (or --producer
    spectral for the pseudo-spectral NS solver) on a wall-stretched
    non-uniform grid, streamed to the co-located store every 2 steps;
  * consumer: QuadConv autoencoder (2 blocks, 5-layer filter MLPs, latent
    per --latent) trained with Adam/MSE on batches sampled from the store,
    validation on one held-out tensor per epoch (paper protocol);
  * after training: the encoder is registered in the store's model registry
    and the simulation encodes subsequent snapshots at runtime — the
    paper's "richer time history" use-case;
  * prints the Tables-1/2-style overhead report and the convergence curve
    (paper Fig. 10 analogue).

A few hundred epochs on the small grid takes a few minutes on CPU and the
loss drops >10x; the paper's 2-orders-of-magnitude drop needs its 500-epoch
/ 36M-element setup.
"""

import argparse

from repro.launch.insitu import run

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--sim-steps", type=int, default=400)
    ap.add_argument("--latent", type=int, default=16)
    ap.add_argument("--producer", choices=["flatplate", "spectral"],
                    default="flatplate")
    ap.add_argument("--points", choices=["small", "medium"], default="small")
    args = ap.parse_args()
    run(epochs=args.epochs, sim_steps=args.sim_steps, latent=args.latent,
        producer=args.producer, points=args.points)
