"""END-TO-END DRIVER (paper §4): in-situ training of the QuadConv
autoencoder from a live flow simulation, then in-situ inference.

Run:  PYTHONPATH=src python examples/insitu_autoencoder.py [--epochs 150]

This is the paper's headline experiment at laptop scale, as one
declarative session (the ~10 lines below): a producer streaming synthetic
turbulent flat-plate snapshots (or ``--producer spectral`` for the
pseudo-spectral NS solver) into the co-located store, the QuadConv
autoencoder trainer consuming them asynchronously, and an inference
component encoding post-training snapshots with the freshly registered
encoder (the paper's "richer time history" use-case).  The session's plan
picks the fused tiers — chunked ``capture_scan`` producers, one-dispatch
epochs — and prints the Tables-1/2-style overhead report.

A few hundred epochs on the small grid takes a few minutes on CPU and the
loss drops >10x; the paper's 2-orders-of-magnitude drop needs its
500-epoch / 36M-element setup.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import TableSpec
from repro.core.store import make_key
from repro.insitu import InferenceConsumer, InSituSession, TrainerConsumer, \
    Producer
from repro.ml import autoencoder as ae
from repro.ml import trainer as tr
from repro.sim import flatplate as fp

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--sim-steps", type=int, default=400)
    ap.add_argument("--latent", type=int, default=16)
    ap.add_argument("--producer", choices=["flatplate", "spectral"],
                    default="flatplate")
    ap.add_argument("--points", choices=["small", "medium"], default="small")
    args = ap.parse_args()

    if args.producer == "spectral" or args.points == "medium":
        # the launcher knows how to build the fancier producers
        from repro.launch.insitu import run
        run(epochs=args.epochs, sim_steps=args.sim_steps,
            latent=args.latent, producer=args.producer, points=args.points)
        raise SystemExit(0)

    fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    cfg = tr.TrainerConfig(
        ae=ae.AEConfig(n_points=fcfg.n_points, latent=args.latent,
                       mlp_width=16, mode="ref"),
        epochs=args.epochs, gather=6, batch_size=4, lr=1e-3)

    def sim_step(carry, rank, t):
        return carry, make_key(rank, t), fp.snapshot(fcfg,
                                                     jax.random.key(0), t)

    def feed(client, step):
        mu, sd = client.get_metadata("norm_stats")
        snap = fp.snapshot(fcfg, jax.random.key(0), args.sim_steps + step)
        return (snap.T[None] - mu) / sd

    session = InSituSession(
        tables=[TableSpec("field", shape=(4, fcfg.n_points), capacity=24,
                          engine="ring")],
        components=[
            Producer(sim_step, table="field", steps=args.sim_steps,
                     carry=jnp.zeros(()), emit_every=2),
            TrainerConsumer(cfg, fp.grid_coords(fcfg), model_key="encoder"),
            InferenceConsumer("encoder", feed, steps=5),
        ])
    print(session.plan().describe(), "\n")
    result = session.run(max_wall_s=3600, verbose=True)
    assert result.ok, result.run.components
    z = result.output("inference").last
    cf = ae.compression_factor(cfg.ae)
    print(f"\nin-situ inference: latent {z.shape}, compression {cf:.0f}x")
    print("\n" + result.run.timers.table(
        "In-situ component overheads (paper Tables 1-2 analogue)"))
