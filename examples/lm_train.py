"""LM training example: train a ~100M-param dense model for a few hundred
steps on the synthetic structured corpus, with checkpoint/restart and
in-situ hidden-state capture.

Run:  PYTHONPATH=src python examples/lm_train.py [--steps 300]

The model is the starcoder2 family at ~100M scale (8 layers, d=512) — the
same code path the production configs lower onto the 256-chip mesh.  The
corpus has a deterministic next-token rule, so the loss falling toward 0
demonstrates real learning, not just plumbing.  Halfway through, the run
"crashes" and restarts from the latest async checkpoint to demonstrate the
fault-tolerance path.
"""

import argparse
import dataclasses
import shutil
import tempfile

import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.launch.train import run
from repro.models.config import ModelConfig


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-100m", family="dense",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
        d_ff=2048, vocab=8192,
        pattern=(("attn", "mlp"),), mlp_act="gelu", norm="layernorm",
        attn_chunk=256, remat=False, dtype=jnp.float32)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    import repro.configs.registry as registry
    # register the 100M config under a local name
    import sys
    import types
    mod = types.ModuleType("repro.configs.starcoder2_100m")
    mod.config = config_100m
    mod.smoke_config = config_100m
    sys.modules["repro.configs.starcoder2_100m"] = mod

    ckpt_dir = tempfile.mkdtemp(prefix="lm100m_ckpt_")
    half = args.steps // 2
    print(f"=== phase 1: train {half} steps (async ckpt every 50) ===")
    run("starcoder2_100m", steps=half, batch=args.batch,
        seq_len=args.seq_len, ckpt_dir=ckpt_dir, ckpt_every=50,
        capture=True)
    print("\n=== simulated failure; phase 2: restart from checkpoint ===")
    run("starcoder2_100m", steps=args.steps, batch=args.batch,
        seq_len=args.seq_len, ckpt_dir=ckpt_dir, ckpt_every=50,
        resume=True)
    shutil.rmtree(ckpt_dir, ignore_errors=True)
