"""Quickstart: the in-situ coupling API in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py

Shows the four framework components from paper Fig. 1 — producer, consumer,
in-memory TensorStore, Client — and both coupling modes:
  * in-situ training data flow (send/sample through the store),
  * in-situ inference (the 3-step put/run/get protocol + the fused path).
"""

import jax
import jax.numpy as jnp

from repro.core import Client, InSituDriver, StoreServer, TableSpec
from repro.core.store import make_key

# --- 1. deploy the "database": a device-resident tensor store --------------
server = StoreServer()
server.create_table(TableSpec("field", shape=(256,), capacity=8,
                              engine="ring"))   # streaming snapshots
server.create_table(TableSpec("named", shape=(4,), capacity=16,
                              engine="hash"))   # named tensors

# --- 2. a producer rank sends its per-step contribution --------------------
sim = Client(server, rank=0)
for step in range(12):
    snapshot = jnp.sin(jnp.linspace(0, 3.14, 256) * (step + 1))
    sim.send_step("field", step, snapshot)       # one line, like SmartRedis
print("watermark after 12 sends:", sim.watermark("field"))

# --- 3. a consumer rank samples a training batch ---------------------------
ml = Client(server, rank=1)
batch, keys, ok = ml.sample_batch("field", n=4, rng=jax.random.key(0))
print("sampled batch:", batch.shape, "ok:", bool(ok))
latest, _, _ = ml.latest_batch("field", n=2)
print("two freshest snapshots, first values:", latest[:, 0])

# --- 4. named tensors + metadata -------------------------------------------
sim.put_tensor("bc.inflow", jnp.array([1.0, 0.0, 0.0, 0.5]), table="named")
val, found = ml.get_tensor("bc.inflow", table="named")
print("named tensor roundtrip:", bool(found), val)
sim.put_metadata("re_tau", 400.0)
print("metadata:", ml.get_metadata("re_tau"))

# --- 5. in-situ inference: the model lives in the store --------------------
def tiny_model(params, x):
    return jnp.tanh(x @ params["w"])

ml.set_model("surrogate", tiny_model,
             {"w": jax.random.normal(jax.random.key(1), (256, 8)) * 0.1})

# paper's 3-step protocol (each step one call):
server.create_table(TableSpec("infer_in", shape=(1, 256), capacity=2,
                              engine="hash"))
server.create_table(TableSpec("infer_out", shape=(1, 8), capacity=2,
                              engine="hash"))
x = snapshot[None]
sim.put_tensor("x", x, table="infer_in")                       # 1) send
sim.run_model("surrogate", inputs=["x"], outputs=["y"],
              table="infer_in", out_table="infer_out")         # 2) evaluate
y, _ = sim.get_tensor("y", table="infer_out")                  # 3) retrieve
print("3-step inference:", y.shape)

# fused fast path (beyond-paper: one dispatch, still model-agnostic):
y2 = sim.infer("surrogate", x)
print("fused inference matches:", bool(jnp.allclose(y, y2, atol=1e-6)))

print("\ncomponent timers:")
print(sim.timers.table())
