"""Quickstart: the declarative in-situ coupling API in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py

The paper's pitch is that coupling simulation and ML is "a single call …
each requiring a single line of code".  Here that call is an
``InSituSession``: declare *what* runs (producer / trainer / inference
components plus tables), ask the plan how it *will* run, then run it.
The raw SmartRedis-style verbs remain available underneath for
control-plane traffic (shown at the end).
"""

import jax
import jax.numpy as jnp

from repro.core import Client, StoreServer, TableSpec
from repro.core.store import make_key
from repro.insitu import InSituSession, Producer, TrainerConsumer
from repro.ml import autoencoder as ae
from repro.ml import trainer as tr
from repro.sim import flatplate as fp

# --- 1. declare the whole workflow: tables + components --------------------
fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)


def sim_step(carry, rank, t):
    """One solver step: advance, return (carry, key, snapshot)."""
    return carry, make_key(rank, t), fp.snapshot(fcfg, jax.random.key(0), t)


cfg = tr.TrainerConfig(
    ae=ae.AEConfig(n_points=fcfg.n_points, mode="ref", latent=16,
                   mlp_width=16),
    epochs=3, gather=6, batch_size=4, lr=1e-3)

session = InSituSession(
    tables=[TableSpec("field", shape=(4, fcfg.n_points), capacity=16,
                      engine="ring")],
    components=[
        Producer(sim_step, table="field", steps=24, carry=jnp.zeros(()),
                 emit_every=2),
        TrainerConsumer(cfg, fp.grid_coords(fcfg), model_key="encoder"),
    ])

# --- 2. the plan says HOW it will run (tiers picked, dispatches predicted) -
plan = session.plan()
print(plan.describe())
print("predicted store dispatches:", plan.store_dispatches)

# --- 3. run it: producer thread + trainer thread, coupled via the store ----
result = session.run(max_wall_s=300)
assert result.ok, result.run.components
trained = result.output("trainer")
print(f"trained {trained.steps} epochs, "
      f"final val relF {trained.history[-1].val_rel_error:.3f}")
print("measured store dispatches:", result.server.stats()["op_count"])

# --- 4. in-situ inference with the registered model ------------------------
client = result.client()
mu, sd = client.get_metadata("norm_stats")
x = (fp.snapshot(fcfg, jax.random.key(0), 99).T[None] - mu) / sd
z = client.infer("encoder", x)                  # fused: one dispatch
print("encoded latent:", z.shape)

# --- 5. the per-verb layer underneath (SmartRedis-style, for control plane)
server = StoreServer()
server.create_table(TableSpec("named", shape=(4,), capacity=16,
                              engine="hash"))
sim, ml = Client(server, rank=0), Client(server, rank=1)
sim.put_tensor("bc.inflow", jnp.array([1.0, 0.0, 0.0, 0.5]), table="named")
val, found = ml.get_tensor("bc.inflow", table="named")
print("named tensor roundtrip:", bool(found), val)
sim.put_metadata("re_tau", 400.0)
print("metadata:", ml.get_metadata("re_tau"))

print("\ncomponent timers:")
print(result.run.timers.table())
