"""Beyond-paper bridge: the paper's in-situ compression applied to LM
hidden states.

Run:  PYTHONPATH=src python examples/insitu_lm_compression.py

The paper trains an autoencoder in situ on CFD solution states so the
simulation can store a richer (compressed) time history.  The identical
machinery transplants to LM training telemetry: the TRAINING JOB is the
producer (final hidden states streamed to the co-located store every few
steps), and a small MLP autoencoder is the consumer, learning a compressed
representation online.  Once trained, the registry model compresses
subsequent captures at runtime — activation telemetry at a fraction of the
bytes, with the producer (the LM train loop) never knowing the compressor's
structure.

Everything is the same `core/` substrate as the CFD workflow — the paper's
claim that the framework "was designed to be applicable to any field"
demonstrated literally.
"""

import time

import jax
import jax.numpy as jnp

from repro.core import Client, InSituDriver, TableSpec
from repro.data.pipeline import TokenStream
from repro.launch.steps import make_train_step, model_specs
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt
from repro.train.train_state import init_train_state, make_tx

D_MODEL = 128
CAPTURE_EVERY = 2
LM_STEPS = 60
AE_STEPS = 150
LATENT = 16


def lm_config() -> ModelConfig:
    return ModelConfig(
        name="lm-capture-demo", n_layers=4, d_model=D_MODEL, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=2048,
        pattern=(("attn", "mlp"),), mlp_act="gelu", norm="layernorm",
        attn_chunk=128, remat=False, dtype=jnp.float32)


def main() -> None:
    cfg = lm_config()
    batch, seq = 4, 64
    driver = InSituDriver(tables=[
        TableSpec("hidden", shape=(batch * seq, D_MODEL), capacity=24,
                  engine="ring"),
    ])

    def lm_producer(client: Client, stop):
        """The LM training job doubles as the in-situ data producer."""
        tx = make_tx(cfg, total_steps=LM_STEPS)
        state = init_train_state(jax.random.key(0), cfg, model_specs(cfg), tx)
        step_fn = jax.jit(make_train_step(cfg), donate_argnums=0)
        capture = jax.jit(lambda p, t: lm.forward(p, cfg, t)[0])
        stream = iter(TokenStream(cfg.vocab, batch, seq, seed=1))
        for i in range(LM_STEPS):
            if stop.is_set():
                break
            raw = next(stream)
            b = {k: jnp.asarray(v) for k, v in raw.items()}
            state, metrics = step_fn(state, b)
            if i % CAPTURE_EVERY == 0:
                h = capture(state.params, b["tokens"])       # [B,S,D]
                client.send_step("hidden", i, h.reshape(-1, D_MODEL))
            if i % 20 == 0:
                print(f"  [lm] step {i:3d} loss {float(metrics['loss']):.3f}")
        return LM_STEPS

    def ae_consumer(client: Client, stop):
        """Tiny MLP autoencoder learns the hidden-state manifold online."""
        client.wait_for_data("hidden", minimum=2, timeout=60)
        key = jax.random.key(7)
        k1, k2 = jax.random.split(key)
        params = {
            "enc": jax.random.normal(k1, (D_MODEL, LATENT)) / D_MODEL**0.5,
            "dec": jax.random.normal(k2, (LATENT, D_MODEL)) / LATENT**0.5,
        }

        def loss_fn(p, x):
            z = jnp.tanh(x @ p["enc"])
            rec = z @ p["dec"]
            return jnp.mean((rec - x) ** 2) / jnp.mean(x ** 2)

        tx = opt.adam(3e-3)
        st = tx.init(params)
        step = jax.jit(lambda p, s, x: _update(p, s, x))

        def _update(p, s, x):
            l, g = jax.value_and_grad(loss_fn)(p, x)
            u, s = tx.update(g, s, p)
            return opt.apply_updates(p, u), s, l

        rng = jax.random.key(3)
        first = last = None
        for i in range(AE_STEPS):
            if stop.is_set():
                break
            rng, k = jax.random.split(rng)
            xs, _, ok = client.sample_batch("hidden", 2, k)
            x = xs.reshape(-1, D_MODEL)
            params, st, l = step(params, st, x)
            if first is None:
                first = float(l)
            last = float(l)
            if i % 50 == 0:
                print(f"  [ae] step {i:3d} rel-mse {float(l):.4f}")
        print(f"  [ae] rel-mse {first:.4f} -> {last:.4f} "
              f"({D_MODEL / LATENT:.0f}x compression)")
        assert last < first
        client.set_model("h-compressor",
                         lambda p, x: jnp.tanh(x @ p["enc"]), params)
        return AE_STEPS

    print("=== in-situ LM hidden-state compression "
          "(paper §4 transplanted) ===")
    res = driver.run({"lm": lm_producer, "compressor": ae_consumer},
                     max_wall_s=900)
    assert res.ok, {k: v.error for k, v in res.components.items()}

    # runtime compression of fresh captures via the registry
    client = driver.client(rank=9)
    xs, _, _ = client.latest_batch("hidden", 1)
    t0 = time.perf_counter()
    z = client.infer("h-compressor", xs[0])
    jax.block_until_ready(z)
    print(f"runtime compression: {xs[0].shape} -> {z.shape} in "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms")
    print("\n" + res.timers.table("component timers"))


if __name__ == "__main__":
    main()
