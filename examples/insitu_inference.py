"""In-situ inference (paper §3.2 + Fig. 1b): a simulation evaluates an ML
model through the store at runtime, staying agnostic of its structure.

Run:  PYTHONPATH=src python examples/insitu_inference.py

* Loads ResNet50 (the paper's benchmark model) into the ModelRegistry.
* A reproducer loop emulates the solver: integrate (sleep) → send inference
  data → run_model → retrieve predictions, every step.
* Compares the paper's 3-step protocol against the in-line (LibTorch
  analogue) call and our fused registry path, reproducing Fig. 7's
  trade-off: the loosely-coupled path costs more per call, but the
  integration is ~5 lines and framework-agnostic.
"""

import time

import jax
import jax.numpy as jnp

from repro.core import Client, StoreServer, TableSpec
from repro.core.telemetry import Timers
from repro.ml.resnet import apply_resnet50, init_resnet50
from repro.sim.reproducer import ReproducerConfig, run_inference

BATCH = 2

print("initializing ResNet50 (paper's inference benchmark model)...")
params = init_resnet50(jax.random.key(0))
server = StoreServer()
client = Client(server)
client.set_model("resnet50", apply_resnet50, params)

x = jax.random.normal(jax.random.key(1), (BATCH, 3, 224, 224))
cfg = ReproducerConfig(n_ranks=1, iterations=5, warmup=1, compute_s=0.02)

print(f"\n-- three-step protocol (paper Fig. 1b), batch={BATCH} --")
timers = run_inference(cfg, server, "resnet50", x, fused=False)
print(timers.table())

print("\n-- fused registry path (beyond-paper single dispatch) --")
timers_fused = run_inference(cfg, server, "resnet50", x, fused=True)
print(timers_fused.table())

print("\n-- in-line baseline (tightly-coupled LibTorch analogue) --")
inline = jax.jit(apply_resnet50)
t = Timers()
jax.block_until_ready(inline(params, x))
for _ in range(5):
    with t.time("inline_eval") as box:
        box[0] = inline(params, x)
print(t.table())

total_3step = (timers.mean("send") + timers.mean("model_eval")
               + timers.mean("retrieve"))
print(f"\n3-step total {total_3step*1e3:.1f} ms vs in-line "
      f"{t.mean('inline_eval')*1e3:.1f} ms "
      f"({total_3step/t.mean('inline_eval'):.2f}x — paper saw 2–4.6x) "
      f"vs fused {timers_fused.mean('model_eval')*1e3:.1f} ms")
