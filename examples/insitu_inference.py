"""In-situ inference (paper §3.2 + Fig. 1b): a simulation evaluates an ML
model through the store at runtime, staying agnostic of its structure.

Run:  PYTHONPATH=src python examples/insitu_inference.py

* Loads ResNet50 (the paper's benchmark model) into the model registry.
* Declares the same ``InferenceConsumer`` twice — once forced onto the
  paper's three-step protocol (put → run_model → get, each one client
  call through scratch tables), once on the fused registry tier — and
  lets the session plan run each.
* Compares both against the in-line (LibTorch analogue) call,
  reproducing Fig. 7's trade-off: the loosely-coupled path costs more
  per call, but the integration is ~5 lines and framework-agnostic.
"""

import jax

from repro.core.telemetry import Timers
from repro.insitu import InferenceConsumer, InSituSession
from repro.ml.resnet import apply_resnet50, init_resnet50

BATCH = 2
ITERS = 5

print("initializing ResNet50 (paper's inference benchmark model)...")
params = init_resnet50(jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (BATCH, 3, 224, 224))


def run_tier(tier: str) -> Timers:
    session = InSituSession(components=[
        InferenceConsumer("resnet50", lambda client, step: x,
                          steps=ITERS, wait_meta=None, tier=tier),
    ])
    # no trainer in this session: preload the model into the registry
    result = session.run(max_wall_s=600, sequential=True,
                         preload=lambda server: server.set_model(
                             "resnet50", apply_resnet50, params))
    assert result.ok, result.run.components
    return result.run.timers


print(f"\n-- three-step protocol (paper Fig. 1b), batch={BATCH} --")
timers = run_tier("three_step")
print(timers.table())

print("\n-- fused registry path (beyond-paper single dispatch) --")
timers_fused = run_tier("fused_registry")
print(timers_fused.table())

print("\n-- in-line baseline (tightly-coupled LibTorch analogue) --")
inline = jax.jit(apply_resnet50)
t = Timers()
jax.block_until_ready(inline(params, x))
for _ in range(ITERS):
    with t.time("inline_eval") as box:
        box[0] = inline(params, x)
print(t.table())

total_3step = (timers.mean("send") + timers.mean("model_eval")
               + timers.mean("retrieve"))
print(f"\n3-step total {total_3step*1e3:.1f} ms vs in-line "
      f"{t.mean('inline_eval')*1e3:.1f} ms "
      f"({total_3step/t.mean('inline_eval'):.2f}x — paper saw 2–4.6x) "
      f"vs fused {timers_fused.mean('model_eval')*1e3:.1f} ms")
